"""Decode-step pipeline simulator (paper §5).

Steady-state model of one serving engine decode step under each scheduling
policy, priced by the bridge law.  The per-step anatomy follows vLLM's:

    prepare inputs (host CPU + small H2D crossings: scatter-index and
    sampling-index tensors) -> forward+sample (GPU) -> output drain (D2H).

What each policy does with that anatomy:

  SYNC_DRAIN      forward, sample, one small D2H, drain, continue — strictly
                  sequential.  Every crossing finds an idle channel and a warm
                  (REGISTERED) staging slot.
  ASYNC_OVERLAP   overlap step-N drain with step-N+1 prep on extra streams.
                  CC-off this hides prep + drain behind forward (plus
                  GPU-side stream pipelining at high concurrency).  CC-on the
                  overlap is a fiction: crossings serialize on the secure
                  channel (L1), block the issuing thread (L2), and the async
                  path's per-step fresh allocations put every input crossing
                  on the FRESH staging path (~1.39 ms each, the 44x class of
                  §5.2) — while the stream-arbitration overhead remains.
  WORKER_DRAIN    v10c: keep async structure, move the *blocking* drain to a
                  worker thread (a blocked crossing releases the GIL).  Host
                  pipelining is restored and input crossings return to the
                  REGISTERED path; the residual vs gold is the GPU-side
                  stream pipelining CC removes regardless of host structure.

The model is linear in the workload's compute terms, so calibration against
a paper table is a least-squares solve (``fit_workload``).  The *law-level*
properties (inversion sign, recovery ordering, streams-flat/contexts-scale)
are structural — they hold for any physically sensible workload and are
checked by property tests.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional

import numpy as np

from .bridge import BridgeModel, BridgeProfile, Crossing, Direction, StagingKind
from .channels import SecureChannelPool, VirtualClock
from .policy import PolicyOutcome, SchedulingPolicy

MS = 1e-3


# ---------------------------------------------------------------------------------
# Global pipeline constants (shared across workloads; see module docstring).
# ---------------------------------------------------------------------------------

#: async-submission overhead CC-off (stream setup amortized per step)
ARB_OFF_MS = 1.0
#: stream-arbitration overhead between in-flight transfers CC-on (per step)
ARB_ON_MS = 0.25
#: worker-thread handoff + queue overhead per step (v10c)
WORKER_HANDOFF_MS = 0.65
#: per-step worker wake latency, amortized by concurrency (at low c the
#: worker wakes once per small drain; at high c drains batch) — calibrated
#: to the §5.5 sweep (v10c barely beats sync at c=128, strongly at c=512)
WORKER_WAKE_MS_AT_256 = 1.3
#: small per-step input crossings — vLLM's scatter-index + sampling-index
#: tensors ("six small fresh-pinned H2D copies per decode step", §5.2)
N_SMALL_H2D = 6
#: auxiliary registered copies per step (copy_ into pre-allocated, 1.2x class)
N_AUX_REG = 14
#: measured per-call CC delta of the 1.2x aux class (31.0 - 25.1 us, §5.2)
AUX_CC_DELTA_S = 5.9e-6


@dataclass(frozen=True)
class ServingWorkload:
    """Calibrated decode-step terms for one (model, concurrency) workload."""

    name: str
    concurrency: int
    forward_ms: float            # GPU forward+sample per step (CC parity, L5)
    prep_cpu_ms: float           # host-side prep compute per step
    gpu_stream_gain_ms: float    # GPU-side pipelining async adds CC-off only
    small_bytes: int = 64        # per small input crossing
    drain_bytes: int = 512       # sampled-token drain per step (§5.4)
    eff_tokens_per_step: float = 0.0   # occupancy x concurrency; 0 -> 0.863*c
    #: small per-step input crossings; MoE adds routing-metadata crossings
    #: ("irreducible bridge traffic at the framework level", §5.4)
    n_small_h2d: int = N_SMALL_H2D
    #: where forward_ms came from: "calibrated" (free least-squares term,
    #: the historical path) or "roofline" (``eff x ComputeModel`` — the one
    #: pricing source the engine's clock also charges; DESIGN.md §10)
    forward_source: str = "calibrated"
    #: measured forward as a multiple of the ideal roofline step (>= 1 means
    #: below roofline; ``1/roofline_eff`` is the MFU/MBU-style achieved
    #: fraction).  Meaningful only when forward_source == "roofline".
    roofline_eff: float = 0.0

    @property
    def tokens_per_step(self) -> float:
        return self.eff_tokens_per_step or 0.863 * self.concurrency


@dataclass(frozen=True)
class StepBreakdown:
    """Per-step time attribution (seconds) — what the accounting loop reads."""

    forward: float
    prep_cpu: float
    small_crossings: float
    aux_crossings: float
    drain: float
    arbitration: float
    hidden: float                # overlapped work (subtracted from the sum)

    @property
    def tpot(self) -> float:
        return (
            self.forward + self.prep_cpu + self.small_crossings
            + self.aux_crossings + self.drain + self.arbitration - self.hidden
        )


def _crossing_times(bridge: BridgeModel, w: ServingWorkload) -> dict[str, float]:
    small_reg = bridge.crossing_time(
        Crossing(w.small_bytes, Direction.H2D, StagingKind.REGISTERED))
    small_fresh = bridge.crossing_time(
        Crossing(w.small_bytes, Direction.H2D, StagingKind.FRESH))
    drain = bridge.crossing_time(
        Crossing(w.drain_bytes, Direction.D2H, StagingKind.REGISTERED))
    aux_delta = AUX_CC_DELTA_S if bridge.cc_on else 0.0
    return {
        "small_reg": small_reg,
        "small_fresh": small_fresh,
        "drain": drain,
        "aux": N_AUX_REG * aux_delta,
    }


def step_breakdown(
    policy: SchedulingPolicy, bridge: BridgeModel, w: ServingWorkload
) -> StepBreakdown:
    """Steady-state decode-step time under `policy` on `bridge`."""
    t = _crossing_times(bridge, w)
    fwd = w.forward_ms * MS
    prep = w.prep_cpu_ms * MS

    if policy is SchedulingPolicy.SYNC_DRAIN:
        # fully sequential, drained: idle channel, warm staging (§5.4)
        return StepBreakdown(
            forward=fwd, prep_cpu=prep,
            small_crossings=w.n_small_h2d * t["small_reg"],
            aux_crossings=t["aux"], drain=t["drain"],
            arbitration=0.0, hidden=0.0,
        )

    if policy is SchedulingPolicy.ASYNC_OVERLAP:
        if not bridge.cc_on:
            # overlap hides prep + crossings + drain behind forward, plus
            # GPU-side stream pipelining; floor is the forward itself.
            host = prep + w.n_small_h2d * t["small_fresh"] + t["drain"]
            hidden = min(host, fwd) + w.gpu_stream_gain_ms * MS
            return StepBreakdown(
                forward=fwd, prep_cpu=prep,
                small_crossings=w.n_small_h2d * t["small_fresh"],
                aux_crossings=t["aux"], drain=t["drain"],
                arbitration=ARB_OFF_MS * MS, hidden=hidden,
            )
        # CC-on: crossings block the engine thread after sampling (their
        # completion gates the next forward), fresh staging each step; the
        # only overlap that survives is host CPU prep behind the forward.
        return StepBreakdown(
            forward=fwd, prep_cpu=prep,
            small_crossings=w.n_small_h2d * t["small_fresh"],
            aux_crossings=t["aux"], drain=t["drain"],
            arbitration=ARB_ON_MS * MS, hidden=min(prep, fwd),
        )

    if policy is SchedulingPolicy.WORKER_DRAIN:
        if not bridge.cc_on:
            # CC-off the worker thread is just async with extra handoff
            b = step_breakdown(SchedulingPolicy.ASYNC_OVERLAP, bridge, w)
            return replace(b, arbitration=b.arbitration + WORKER_HANDOFF_MS * MS)
        # v10c: drain blocked on worker thread; engine pipelines prep; input
        # crossings return to the warm path; GPU stream pipelining stays lost.
        handoff = (WORKER_HANDOFF_MS
                   + WORKER_WAKE_MS_AT_256 * 256.0 / max(1, w.concurrency)) * MS
        return StepBreakdown(
            forward=fwd, prep_cpu=prep,
            small_crossings=w.n_small_h2d * t["small_reg"],
            aux_crossings=t["aux"], drain=t["drain"],
            arbitration=handoff,
            hidden=min(prep + t["drain"], fwd),
        )

    raise ValueError(f"unknown policy {policy}")


def tpot_ms(policy: SchedulingPolicy, bridge: BridgeModel, w: ServingWorkload) -> float:
    return step_breakdown(policy, bridge, w).tpot / MS


def tokens_per_s(policy: SchedulingPolicy, bridge: BridgeModel, w: ServingWorkload) -> float:
    return w.tokens_per_step / step_breakdown(policy, bridge, w).tpot


def simulate_matrix(
    profile: BridgeProfile, w: ServingWorkload,
    policies: tuple[SchedulingPolicy, ...] = (
        SchedulingPolicy.ASYNC_OVERLAP, SchedulingPolicy.SYNC_DRAIN,
        SchedulingPolicy.WORKER_DRAIN,
    ),
) -> list[PolicyOutcome]:
    out = []
    for cc_on in (False, True):
        bridge = BridgeModel(profile, cc_on=cc_on)
        for p in policies:
            out.append(PolicyOutcome(p, cc_on, tokens_per_s(p, bridge, w)))
    return out


# ---------------------------------------------------------------------------------
# One pricing source (DESIGN.md §10): the simulator's forward term is the same
# ComputeModel roofline the engine's clock charges.  A calibrated workload is a
# roofline step scaled by one dimensionless achieved-efficiency factor, so the
# §5 tables and the engine can never price the forward from different models.
# ---------------------------------------------------------------------------------


def roofline_forward_ms(cfg, profile: BridgeProfile, batch: int, *,
                        kv_len: float = 0.0, spec=None) -> float:
    """One decode step's forward time (ms) from the ComputeModel roofline.

    Priced CC-off (device-local work is at parity, L5 — the ``forward_ms``
    the step model carries is policy- and CC-independent by construction).
    """
    from .compute import ComputeModel
    cm = ComputeModel(cfg, BridgeModel(profile, cc_on=False), spec=spec)
    return cm.decode_step_s(batch, kv_len=kv_len) / MS


def roofline_workload(name: str, cfg, profile: BridgeProfile,
                      concurrency: int, *, kv_len: float = 0.0,
                      eff: float = 1.0, prep_cpu_ms: float = 0.0,
                      gpu_stream_gain_ms: float = 0.0,
                      **kw) -> ServingWorkload:
    """Build a workload whose forward term is ``eff x`` the ComputeModel
    roofline step — no measured table required (the bench_packed sweep uses
    this to price arbitrary config x batch x length cells)."""
    fwd = eff * roofline_forward_ms(cfg, profile, concurrency, kv_len=kv_len)
    return ServingWorkload(
        name, concurrency, forward_ms=fwd, prep_cpu_ms=prep_cpu_ms,
        gpu_stream_gain_ms=gpu_stream_gain_ms,
        forward_source="roofline", roofline_eff=eff, **kw)


# ---------------------------------------------------------------------------------
# Calibration: the step model is linear in (forward, prep_cpu, gpu_stream_gain),
# so fitting a workload to measured table cells is a least-squares solve.
# ---------------------------------------------------------------------------------

@dataclass(frozen=True)
class Observation:
    policy: SchedulingPolicy
    cc_on: bool
    tpot_ms: Optional[float] = None        # either TPOT...
    tokens_per_s: Optional[float] = None   # ...or throughput (converted)


def fit_workload(
    name: str, concurrency: int, profile: BridgeProfile,
    observations: list[Observation], *, eff_tokens_per_step: float = 0.0,
    n_small_h2d: int = N_SMALL_H2D, cfg=None, kv_len: float = 0.0,
) -> ServingWorkload:
    """Fit (forward, prep_cpu, gpu_stream_gain) to measured table cells.

    The step model is *piecewise* linear (the overlap `min` terms), so the
    fit is a damped Gauss-Newton around the current iterate rather than one
    linear solve.  Converges in a handful of iterations for every paper table
    (the pieces are flat and the tables are near-consistent with the model).

    With a ``cfg`` (ModelConfig), the forward term is not a free millisecond
    count: the fit solves for a dimensionless achieved-efficiency factor on
    the ComputeModel roofline step (``forward_ms = eff x roofline``) — the
    same reparameterized linear space, so the fitted workload is numerically
    identical, but the §5 tables and the engine's clock now share one
    pricing source and the fit's residual is an honest MFU/MBU-style
    statement (``roofline_eff``) instead of an unanchored constant.
    """
    probe = ServingWorkload(name, concurrency, 0.0, 0.0, 0.0,
                            eff_tokens_per_step=eff_tokens_per_step,
                            n_small_h2d=n_small_h2d)
    tps_const = probe.tokens_per_step
    #: ms of forward per unit of x[0]: the roofline step when anchored to a
    #: config, 1.0 (x[0] is itself the ms) on the legacy free-term path
    base_ms = (roofline_forward_ms(cfg, profile, concurrency, kv_len=kv_len)
               if cfg is not None else 1.0)

    targets = []
    for obs in observations:
        target = obs.tpot_ms
        if target is None:
            if obs.tokens_per_s is None:
                raise ValueError("observation needs tpot_ms or tokens_per_s")
            target = tps_const / obs.tokens_per_s / MS
        targets.append((obs.policy, obs.cc_on, target))

    bridges = {cc: BridgeModel(profile, cc_on=cc) for cc in (False, True)}

    def predict(x: np.ndarray) -> np.ndarray:
        w = replace(probe, forward_ms=float(x[0]) * base_ms,
                    prep_cpu_ms=float(x[1]),
                    gpu_stream_gain_ms=float(x[2]))
        return np.array([
            step_breakdown(p, bridges[cc], w).tpot / MS for p, cc, _ in targets])

    y = np.array([t for _, _, t in targets])
    # init: forward = 80% of fastest cell, small prep, small gain
    x = np.array([0.8 * y.min() / base_ms, 0.15 * y.min(), 0.5])
    eps = 1e-3
    for _ in range(60):
        f0 = predict(x)
        J = np.zeros((len(targets), 3))
        for i in range(3):
            dx = np.zeros(3)
            dx[i] = eps
            J[:, i] = (predict(x + dx) - f0) / eps
        # damped least-squares step
        JTJ = J.T @ J + 1e-6 * np.eye(3)
        step = np.linalg.solve(JTJ, J.T @ (y - f0))
        x = np.clip(x + 0.8 * step, 0.0, None)
        if np.linalg.norm(step) < 1e-9:
            break
    fwd, prep, gain = float(x[0]) * base_ms, float(x[1]), float(x[2])
    return ServingWorkload(
        name, concurrency, forward_ms=fwd, prep_cpu_ms=prep,
        gpu_stream_gain_ms=gain, eff_tokens_per_step=eff_tokens_per_step,
        n_small_h2d=n_small_h2d,
        forward_source="roofline" if cfg is not None else "calibrated",
        roofline_eff=float(x[0]) if cfg is not None else 0.0,
    )


# ---------------------------------------------------------------------------------
# Microbenchmark simulator: the streams-flat / contexts-scale curves (§4.2, Fig 2)
# ---------------------------------------------------------------------------------

def small_copy_latency_us(
    profile: BridgeProfile, cc_on: bool, n_streams: int,
    direction: Direction = Direction.D2H,
) -> float:
    """Per-copy latency of 32-byte same-context copies vs stream count (L1)."""
    bridge = BridgeModel(profile, cc_on=cc_on)
    return bridge.stream_scaling(direction, n_streams) / 1e-6


def context_scaling_curve(
    profile: BridgeProfile, cc_on: bool, context_counts: list[int],
    direction: Direction = Direction.H2D,
) -> list[float]:
    """Aggregate sustained bandwidth (GB/s) vs number of contexts (L4)."""
    bridge = BridgeModel(profile, cc_on=cc_on)
    return [bridge.aggregate_bandwidth(direction, n) / 1e9 for n in context_counts]


def sustained_transfer_event_sim(
    profile: BridgeProfile, cc_on: bool, *, n_contexts: int, n_chunks: int = 64,
    chunk_bytes: int = 256 << 20, direction: Direction = Direction.H2D,
) -> float:
    """Event-driven check of the analytic law: fan `n_chunks` large copies
    over a context pool and measure achieved GB/s.  Returns bandwidth in GB/s.
    """
    bridge = BridgeModel(profile, cc_on=cc_on)
    clock = VirtualClock()
    pool = SecureChannelPool(bridge, n_workers=n_contexts, clock=clock)
    pool.prewarm()
    done = 0.0
    for _ in range(n_chunks):
        done = max(done, pool.submit(
            Crossing(chunk_bytes, direction, StagingKind.REGISTERED)))
    total_bytes = n_chunks * chunk_bytes
    # ceiling: aggregate over the pool cannot exceed the systemic cap
    elapsed = max(done, total_bytes / bridge.aggregate_bandwidth(direction, n_contexts))
    return total_bytes / elapsed / 1e9
