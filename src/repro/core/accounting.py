"""Profiler accounting loop (paper §5.2).

The paper attributes the dense-decode CC gap by grouping profiled copy calls
into op classes and checing that (per-call delta x call count) closes the
observed end-to-end slowdown: 1,138 `aten::_to_copy` calls x 1,357 us/call =
1.54 s of the 1.56 s gap.

This module is the reusable form of that loop: the serving engine's
``TransferGateway`` records every crossing with its op class; ``attribute``
produces the Table-5.2-style accounting and verifies closure.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Iterable

US = 1e-6


@dataclass(frozen=True)
class CopyRecord:
    """One profiled crossing.

    The first four fields are the §5.2 accounting-loop minimum; the rest are
    the bridge-tape extension (trace/tape.py): where the crossing ran and
    when, so a recorded stream can be replayed, re-priced and checked against
    the bridge-law invariants.  Defaults keep hand-built accounting records
    (benchmarks) valid.
    """

    op_class: str       # e.g. "alloc_h2d" (fresh), "prealloc_copy", "prep_pinned"
    nbytes: int
    duration_s: float
    cc_on: bool
    direction: str = ""         # "h2d" | "d2h" ("" = unknown, pre-tape record)
    staging: str = ""           # "fresh" | "registered"
    channel: int = -1           # secure-channel/context id; -1 = engine-serial path
    t_start: float = 0.0        # virtual-clock interval of the crossing
    t_end: float = 0.0
    charged: bool = True        # False: wall-clock charge accounted elsewhere
    #: free-form provenance tags (e.g. arena_hit/arena_miss staging outcome)
    tags: tuple = ()
    #: interval kind: "crossing" (bridge traffic) or "compute" (device-local
    #: prefill/decode work priced by core.compute.ComputeModel — no bytes
    #: cross the bridge; direction/staging are empty by construction)
    kind: str = "crossing"
    #: which roofline term won for a compute record ("compute" | "memory";
    #: "" = unknown/crossing) — lets replay re-price at the matching parity
    #: factor instead of conservatively assuming compute-bound
    bound: str = ""
    #: constituent crossings fused into this one, as (op_class, nbytes)
    #: pairs — set by the coalescer so a fused flush stays un-fusable
    #: counterfactually (stall attribution, replay).  Empty for ordinary
    #: crossings; additive with default, so hand-built records stay valid.
    sources: tuple = ()
    #: quantized crossings (DESIGN.md §13): the full-width byte count the
    #: payload represents.  `nbytes` is what crossed the wire; `raw_bytes`
    #: is what it widens back to on device.  0 = not quantized (every
    #: pre-quant record), and the conformance Q-law demands
    #: 0 < nbytes <= raw_bytes whenever it is set.
    raw_bytes: int = 0
    #: codec id ("fp8" | "int8") for quantized crossings; "" otherwise
    codec: str = ""


@dataclass
class OpClassRow:
    op_class: str
    calls: int
    cc_off_avg_us: float
    cc_on_avg_us: float

    @property
    def per_call_slowdown(self) -> float:
        return self.cc_on_avg_us / max(self.cc_off_avg_us, 1e-9)

    @property
    def total_delta_s(self) -> float:
        return (self.cc_on_avg_us - self.cc_off_avg_us) * US * self.calls


@dataclass
class Attribution:
    rows: list[OpClassRow]
    total_gap_s: float

    @property
    def explained_s(self) -> float:
        return sum(r.total_delta_s for r in self.rows)

    @property
    def closure(self) -> float:
        """Fraction of the end-to-end gap explained by the op-class deltas."""
        if self.total_gap_s <= 0:
            return 1.0
        return self.explained_s / self.total_gap_s

    def dominant(self) -> OpClassRow:
        return max(self.rows, key=lambda r: r.total_delta_s)


def attribute(
    cc_off_records: Iterable[CopyRecord],
    cc_on_records: Iterable[CopyRecord],
    total_gap_s: float,
) -> Attribution:
    """Group paired CC-off/CC-on profiles by op class and close the accounting.

    Call counts are taken from the CC-on run (same workload => same counts;
    a mismatch larger than 2% raises, since it means the runs are not paired).
    """
    def group(records: Iterable[CopyRecord]) -> dict[str, list[float]]:
        g: dict[str, list[float]] = defaultdict(list)
        for r in records:
            g[r.op_class].append(r.duration_s)
        return g

    off, on = group(cc_off_records), group(cc_on_records)
    rows = []
    for op_class in sorted(on):
        if op_class not in off:
            raise ValueError(f"op class {op_class!r} missing from CC-off profile")
        n_on, n_off = len(on[op_class]), len(off[op_class])
        if abs(n_on - n_off) > 0.02 * max(n_on, n_off):
            raise ValueError(
                f"unpaired profiles for {op_class!r}: {n_off} CC-off vs {n_on} CC-on calls")
        rows.append(OpClassRow(
            op_class=op_class,
            calls=n_on,
            cc_off_avg_us=sum(off[op_class]) / n_off / US,
            cc_on_avg_us=sum(on[op_class]) / n_on / US,
        ))
    rows.sort(key=lambda r: r.total_delta_s, reverse=True)
    return Attribution(rows=rows, total_gap_s=total_gap_s)


def format_table(attr: Attribution) -> str:
    lines = [
        f"{'op class':<24}{'calls':>8}{'CC-off avg':>14}{'CC-on avg':>14}{'slowdown':>10}{'delta(s)':>10}"
    ]
    for r in attr.rows:
        lines.append(
            f"{r.op_class:<24}{r.calls:>8}{r.cc_off_avg_us:>12.1f}us{r.cc_on_avg_us:>12.1f}us"
            f"{r.per_call_slowdown:>9.1f}x{r.total_delta_s:>10.3f}"
        )
    lines.append(
        f"explained {attr.explained_s:.3f}s of {attr.total_gap_s:.3f}s gap "
        f"(closure {attr.closure:.1%}); dominant: {attr.dominant().op_class}"
    )
    return "\n".join(lines)
