"""The serialized-bridge performance law (paper §4).

Under confidential computing the host<->accelerator bridge becomes a
serialized, high-setup-cost channel.  Four measured properties define it
(paper §4.4):

  L1  Within a context, cross-device transfers serialize on a fixed pool of
      secure copy channels; stream-level overlap is a fiction under CC.
  L2  Asynchrony is revoked: "non-blocking" copies block the calling CPU
      thread for the full transfer.
  L3  Every crossing pays a fixed setup toll (~330 us observed), so many
      small crossings are catastrophically worse than few large ones.
  L4  Additional bandwidth requires additional contexts, each with an
      expensive secure lifecycle; compute and device-local memory stay at
      parity.

``BridgeProfile`` encodes the constants of that law for a concrete platform;
``BridgeModel`` turns the law into computable transfer times.  The profiles
below are calibrated to the paper's own measurements (B300 HGX, RTX Pro 6000,
H200 boundary check), plus a TPU v5e profile expressing the analogous facts
for the host<->TPU PCIe path (the adaptation target; see DESIGN.md §2).

Everything downstream — the decode-step simulator (simulator.py), the
transfer gateway (gateway.py), the pooled loader (loader/) and the KV-offload
policy (serving/offload.py) — is this law applied at a different layer.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from dataclasses import dataclass
from typing import Optional

US = 1e-6
MS = 1e-3
GB = 1e9
GIB = 1 << 30


class Direction(enum.Enum):
    H2D = "h2d"
    D2H = "d2h"
    #: in-tenant fabric P2P — never transits host memory, so it carries no
    #: staging discipline and no bridge serialization (DESIGN.md §12).  Only
    #: kind="p2p" tape records use it; bridge pricing paths never see it.
    P2P = "p2p"


class StagingKind(enum.Enum):
    """How the host-side staging buffer for a crossing was obtained.

    The paper's profiler accounting (§5.2) shows the toll is a property of
    the *staging path*, not of the byte count:

      * FRESH      — freshly allocated pinned buffer: pays full bounce-buffer
                     setup (~330 us) plus allocation/registration; 44x class.
      * REGISTERED — pre-allocated, previously used staging: warm path, pays
                     only a small per-crossing delta (1.0-1.2x class).
    """

    FRESH = "fresh"
    REGISTERED = "registered"


@dataclass(frozen=True)
class BridgeProfile:
    """Constants of the serialized-bridge law for one platform.

    All times in seconds, all bandwidths in bytes/second.
    """

    name: str

    # ---- native (CC-off) bridge -------------------------------------------------
    native_h2d_bw: float
    native_d2h_bw: float
    #: per-crossing dispatch latency CC-off, registered staging (small-copy floor)
    native_toll: float
    #: fresh pinned-allocation cost CC-off (aten::_to_copy at 31.7 us, §5.2)
    native_fresh_alloc: float
    #: fractional bandwidth gain available from extra streams CC-off (paper: ~24%)
    native_stream_scaling: float

    # ---- CC-on bridge: the serialized channel ------------------------------------
    #: sustained per-context secure-channel bandwidth (one context, large copies)
    cc_channel_h2d_bw: float
    cc_channel_d2h_bw: float
    #: fixed bounce-buffer setup toll per crossing with FRESH staging (L3)
    cc_fresh_toll: float
    #: additional fresh-pinned allocation + registration cost (host side)
    cc_fresh_alloc: float
    #: per-crossing latency with REGISTERED staging (warm small-copy floor)
    cc_registered_toll: float
    #: aggregate ceiling over all contexts, as a fraction of native bw (L4)
    cc_multi_context_ceiling_h2d: float
    cc_multi_context_ceiling_d2h: float
    #: system-wide secure copy channel limit -> max useful contexts
    max_secure_contexts: int

    # ---- context lifecycle (L4) ---------------------------------------------------
    context_create: float      # per context
    context_destroy: float     # per context
    pinned_slot_alloc: float   # per context staging slot

    # ---- device-local parity (the "organizing fact") ------------------------------
    compute_parity: float      # CC-on/CC-off matmul throughput ratio
    hbm_parity: float          # CC-on/CC-off device-memory harness ratio

    # ---- CPU cipher path (§4.3 ablation) -------------------------------------------
    #: duplex plateau with full AES-NI/PCLMUL (GB/s level the channel law sets)
    cipher_duplex_bw: float
    #: collapsed bandwidth with AES-NI+PCLMUL disabled (cipher becomes the limiter)
    cipher_duplex_bw_no_aesni: float
    #: relative cost of disabling only the wide-vector VAES/VPCLMUL forms
    vaes_ablation_cost: float

    # ---- fabric (§7): the path the bridge law does NOT serialize --------------------
    fabric_p2p_bw: float       # NVLink-in-CVM / ICI analogue
    fabric_fallback_bw: float  # CC-compatible TCP fallback (NCCL without NVLink)

    def channel_bw(self, direction: Direction, cc_on: bool) -> float:
        if not cc_on:
            return self.native_h2d_bw if direction is Direction.H2D else self.native_d2h_bw
        return self.cc_channel_h2d_bw if direction is Direction.H2D else self.cc_channel_d2h_bw

    def aggregate_ceiling(self, direction: Direction) -> float:
        frac = (
            self.cc_multi_context_ceiling_h2d
            if direction is Direction.H2D
            else self.cc_multi_context_ceiling_d2h
        )
        native = self.native_h2d_bw if direction is Direction.H2D else self.native_d2h_bw
        return frac * native


# ---------------------------------------------------------------------------------
# Calibrated profiles.  Constants are the paper's own measurements where given;
# derived constants are noted inline.
# ---------------------------------------------------------------------------------

B300 = BridgeProfile(
    name="b300-hgx",
    native_h2d_bw=55.48 * GB,            # §4.1 table
    native_d2h_bw=57.38 * GB,
    native_toll=17.0 * US,               # §4.2 small-copy CC-off single stream
    native_fresh_alloc=14.7 * US,
    native_stream_scaling=0.24,          # §4.2: 17 -> 13 us at 16 streams
    cc_channel_h2d_bw=11.26 * GB,        # §4.1: 0.203x
    cc_channel_d2h_bw=12.08 * GB,        # §4.1: 0.211x
    cc_fresh_toll=330.0 * US,            # §4.2 / §5.2 bounce-buffer setup
    cc_fresh_alloc=1027.0 * US,          # derived: 1389 us aten::_to_copy − 330 toll − ~32 us base
    cc_registered_toll=40.0 * US,        # §4.2 small-copy CC-on floor
    cc_multi_context_ceiling_h2d=0.615,  # §4.1 multiprocess best
    cc_multi_context_ceiling_d2h=0.697,
    max_secure_contexts=24,              # §4.2 context sweep knee / NVIDIA ops guide
    context_create=5.20 / 8,             # §6.1: 5.2 s cuCtxCreate for 8 workers
    context_destroy=3.90 / 8,
    pinned_slot_alloc=0.30 / 8,
    compute_parity=0.998,                # §4.1 BF16 matmul
    hbm_parity=0.912,                    # §4.1 HBM harness
    cipher_duplex_bw=40.4 * GB,          # §4.3
    cipher_duplex_bw_no_aesni=5.5 * GB,
    vaes_ablation_cost=0.034,
    fabric_p2p_bw=510.4 * GB,            # §7.1 NVLink P2P inside CVM
    fabric_fallback_bw=10e6,             # §7.1 NCCL TCP fallback ~10 MB/s
)

RTX_PRO_6000 = BridgeProfile(
    name="rtx-pro-6000",
    native_h2d_bw=55.0 * GB,             # PCIe Gen5 (same class as B300 PCIe path)
    native_d2h_bw=55.0 * GB,
    native_toll=17.0 * US,
    native_fresh_alloc=14.7 * US,
    native_stream_scaling=0.24,
    cc_channel_h2d_bw=11.6 * GB,         # §4.2: "same 11.5-11.7 GB/s level"
    cc_channel_d2h_bw=11.6 * GB,
    cc_fresh_toll=330.0 * US,
    cc_fresh_alloc=1027.0 * US,
    cc_registered_toll=40.0 * US,
    cc_multi_context_ceiling_h2d=0.64,   # §4.2: ~35 GB/s at 24 contexts
    cc_multi_context_ceiling_d2h=0.64,
    max_secure_contexts=24,
    context_create=5.20 / 8,
    context_destroy=3.90 / 8,
    pinned_slot_alloc=0.30 / 8,
    compute_parity=0.998,
    hbm_parity=0.95,
    cipher_duplex_bw=40.4 * GB,
    cipher_duplex_bw_no_aesni=5.5 * GB,
    vaes_ablation_cost=0.034,
    fabric_p2p_bw=0.0,                   # no NVLink on this platform
    fabric_fallback_bw=10e6,
)

H200 = BridgeProfile(
    name="h200",
    native_h2d_bw=55.32 * GB,            # §4.2 boundary experiment
    native_d2h_bw=55.14 * GB,
    native_toll=15.0 * US,
    native_fresh_alloc=14.7 * US,
    native_stream_scaling=0.24,
    cc_channel_h2d_bw=10.03 * GB,
    cc_channel_d2h_bw=10.35 * GB,
    cc_fresh_toll=330.0 * US,
    cc_fresh_alloc=1027.0 * US,
    cc_registered_toll=35.0 * US,        # §4.2: 35 -> 34 us flat
    cc_multi_context_ceiling_h2d=0.62,
    cc_multi_context_ceiling_d2h=0.62,
    max_secure_contexts=24,
    context_create=5.20 / 8,
    context_destroy=3.90 / 8,
    pinned_slot_alloc=0.30 / 8,
    compute_parity=0.998,
    hbm_parity=0.93,
    cipher_duplex_bw=40.4 * GB,
    cipher_duplex_bw_no_aesni=5.5 * GB,
    vaes_ablation_cost=0.034,
    fabric_p2p_bw=0.0,                   # NVLinks blocked in the CC-off comparison
    fabric_fallback_bw=10e6,
)

#: TPU v5e adaptation profile (DESIGN.md §2).  There is no TPU confidential mode;
#: this profile expresses the *analogous* serialized regime for the host<->TPU
#: PCIe path so the same runtime discipline can be exercised and unit-costed:
#: a single per-device transfer stream (streams never scale), a per-`device_put`
#: dispatch+layout toll, and ICI as the fabric path the bridge does not touch.
TPU_V5E = BridgeProfile(
    name="tpu-v5e",
    native_h2d_bw=32.0 * GB,             # PCIe Gen4 x16 host link (per host, 4 chips)
    native_d2h_bw=32.0 * GB,
    native_toll=25.0 * US,               # runtime dispatch + reformat floor
    native_fresh_alloc=20.0 * US,
    native_stream_scaling=0.0,           # single transfer stream per device already
    cc_channel_h2d_bw=8.0 * GB,          # modeled secure-staging regime
    cc_channel_d2h_bw=8.0 * GB,
    cc_fresh_toll=330.0 * US,
    cc_fresh_alloc=1027.0 * US,
    cc_registered_toll=45.0 * US,
    cc_multi_context_ceiling_h2d=0.65,
    cc_multi_context_ceiling_d2h=0.65,
    max_secure_contexts=16,
    context_create=5.20 / 8,
    context_destroy=3.90 / 8,
    pinned_slot_alloc=0.30 / 8,
    compute_parity=1.0,
    hbm_parity=1.0,
    cipher_duplex_bw=40.4 * GB,
    cipher_duplex_bw_no_aesni=5.5 * GB,
    vaes_ablation_cost=0.034,
    fabric_p2p_bw=50.0 * GB,             # one ICI link direction
    fabric_fallback_bw=10e6,
)

PROFILES = {p.name: p for p in (B300, RTX_PRO_6000, H200, TPU_V5E)}


@dataclass(frozen=True)
class Crossing:
    """One host<->device crossing, the unit the bridge law prices."""

    nbytes: int
    direction: Direction
    staging: StagingKind = StagingKind.REGISTERED


class BridgeModel:
    """Computable form of the serialized-bridge law.

    All methods are pure; scheduling across channels is handled by the
    discrete-event simulator (simulator.py) on top of these primitives.
    """

    def __init__(self, profile: BridgeProfile, cc_on: bool, *, aesni: bool = True,
                 vaes: bool = True):
        self.profile = profile
        self.cc_on = cc_on
        self.aesni = aesni
        self.vaes = vaes

    # -- single crossing -----------------------------------------------------------

    def crossing_time(self, crossing: Crossing, *, n_contexts: int = 1) -> float:
        """Wall time for one crossing, given `n_contexts` pooled secure contexts.

        CC-off: toll + bytes/native_bw.
        CC-on : staging toll (FRESH: alloc + 330 us setup; REGISTERED: warm floor)
                + bytes over the secure channel(s), capped by the multi-context
                ceiling and the cipher plateau (§4.3).
        """
        p = self.profile
        if not self.cc_on:
            bw = p.channel_bw(crossing.direction, cc_on=False)
            toll = p.native_toll
            if crossing.staging is StagingKind.FRESH:
                toll += p.native_fresh_alloc
            return toll + crossing.nbytes / bw

        if crossing.staging is StagingKind.FRESH:
            toll = p.cc_fresh_toll + p.cc_fresh_alloc
        else:
            toll = p.cc_registered_toll
        bw = self.aggregate_bandwidth(crossing.direction, n_contexts)
        return toll + crossing.nbytes / bw

    # -- bandwidth law ---------------------------------------------------------------

    def aggregate_bandwidth(self, direction: Direction, n_contexts: int) -> float:
        """Sustained large-transfer bandwidth with ``n_contexts`` contexts (L1+L4).

        One context = one secure channel.  Contexts scale linearly until the
        system ceiling (fraction of native bw); the CPU cipher plateau also
        caps the path (it binds only when AES-NI is ablated — §4.3).
        """
        p = self.profile
        if not self.cc_on:
            return p.channel_bw(direction, cc_on=False)
        n = max(1, min(n_contexts, p.max_secure_contexts))
        linear = n * p.channel_bw(direction, cc_on=True)
        ceiling = p.aggregate_ceiling(direction)
        bw = min(linear, ceiling)
        return min(bw, self._cipher_cap())

    def _cipher_cap(self) -> float:
        p = self.profile
        if not self.aesni:
            return p.cipher_duplex_bw_no_aesni
        cap = p.cipher_duplex_bw
        if not self.vaes:
            cap *= 1.0 - p.vaes_ablation_cost
        return cap

    def stream_scaling(self, direction: Direction, n_streams: int) -> float:
        """Per-copy latency for small same-context copies vs stream count (L1).

        CC-on: flat — streams share one serialized channel (paper: 40 -> 39 us).
        CC-off: modest scaling (paper: 17 -> 13 us at 16 streams, ~24%).
        """
        p = self.profile
        if self.cc_on:
            base = p.cc_registered_toll
            # ~2.5% total improvement from 1 to 16 streams (queueing jitter only)
            frac = 0.025 * (1.0 - 1.0 / max(1, n_streams))
            return base * (1.0 - frac)
        base = p.native_toll
        frac = p.native_stream_scaling * (1.0 - 1.0 / max(1, n_streams))
        return base * (1.0 - frac)

    # -- batch pricing (what the gateway uses) ------------------------------------------

    def batch_time(self, crossings: list[Crossing], *, n_contexts: int = 1) -> float:
        """Serialized cost of a list of crossings within one context pool.

        Under CC, same-context crossings serialize (L1): total = sum of tolls +
        total bytes over the aggregate channel.  CC-off, crossings pipeline on
        abundant DMA: total = max(per-crossing) + queued dispatch.
        """
        if not crossings:
            return 0.0
        if self.cc_on:
            return sum(self.crossing_time(c, n_contexts=n_contexts) for c in crossings)
        # CC-off: dispatch serializes lightly; byte movement pipelines.
        p = self.profile
        dispatch = p.native_toll * len(crossings)
        bytes_by_dir = {d: 0 for d in Direction}
        for c in crossings:
            bytes_by_dir[c.direction] += c.nbytes
        move = max(
            bytes_by_dir[d] / p.channel_bw(d, cc_on=False) for d in Direction
        )
        return dispatch + move

    # -- device-local parity ------------------------------------------------------------

    def compute_time(self, flops: float, peak_flops: float) -> float:
        """Device compute is at parity under CC (L5)."""
        parity = self.profile.compute_parity if self.cc_on else 1.0
        return flops / (peak_flops * parity)

    def hbm_time(self, nbytes: float, hbm_bw: float) -> float:
        parity = self.profile.hbm_parity if self.cc_on else 1.0
        return nbytes / (hbm_bw * parity)

    # -- context lifecycle ---------------------------------------------------------------

    def pool_lifecycle_cost(self, n_workers: int) -> dict[str, float]:
        p = self.profile
        return {
            "create": p.context_create * n_workers,
            "destroy": p.context_destroy * n_workers,
            "pinned_alloc": p.pinned_slot_alloc * n_workers,
        }

    # -- convenience ratios (benchmarks assert these against the paper) --------------------

    def sustained_ratio(self, direction: Direction, *, n_contexts: int = 1) -> float:
        """CC-on / CC-off sustained bandwidth ratio for large transfers."""
        cc = self.aggregate_bandwidth(direction, n_contexts)
        native = self.profile.channel_bw(direction, cc_on=False)
        return cc / native


def bridge_pair(profile: BridgeProfile) -> tuple[BridgeModel, BridgeModel]:
    """(CC-off, CC-on) model pair for a platform."""
    return BridgeModel(profile, cc_on=False), BridgeModel(profile, cc_on=True)
