"""TransferGateway — runtime host<->device crossing discipline (paper §8 rule 1).

"A CC-aware runtime should treat bridge crossings as a scheduled, scarce
resource — batched, drained, and kept off the critical path."

The gateway is the single choke point through which the serving engine, the
loader and the KV-offload policy move bytes across the bridge.  It

  * executes the *real* JAX transfer (``jax.device_put`` / ``np.asarray``),
  * charges the bridge-law cost of the crossing to a virtual clock (so CC
    economics are measurable deterministically on CPU),
  * records a ``CopyRecord`` per crossing for the accounting loop (§5.2),
  * implements the CC-aware disciplines: small-crossing batching, drained
    submission, and context-pooled bulk transfers.

On a real TPU deployment the virtual-clock charge is replaced by the actual
transfer (the discipline is the same); here it lets every policy be costed.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

from .accounting import CopyRecord
from .bridge import BridgeModel, Crossing, Direction, StagingKind
from .channels import P2P_CHANNEL, SecureChannelPool, VirtualClock
from .policy import RuntimeDefaults, SchedulingPolicy


def _nbytes(x: Any) -> int:
    if hasattr(x, "nbytes"):
        return int(x.nbytes)
    return int(np.asarray(x).nbytes)


@dataclass
class GatewayStats:
    h2d_crossings: int = 0
    d2h_crossings: int = 0
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    batched_crossings_saved: int = 0
    bridge_time_s: float = 0.0
    # ---- device-local compute (core.compute.ComputeModel charges) -------------
    compute_charges: int = 0
    compute_time_s: float = 0.0
    # ---- in-tenant fabric P2P (never the bridge; DESIGN.md §12) ---------------
    p2p_crossings: int = 0
    p2p_bytes: int = 0
    p2p_time_s: float = 0.0
    p2p_fallback_crossings: int = 0


class TransferGateway:
    """All host<->device movement goes through here."""

    def __init__(
        self,
        bridge: BridgeModel,
        defaults: RuntimeDefaults,
        *,
        clock: Optional[VirtualClock] = None,
        pool_workers: int = 1,
        device: Optional[jax.Device] = None,
        arena: Optional[Any] = None,
    ):
        self.bridge = bridge
        self.defaults = defaults
        self.clock = clock or VirtualClock()
        self.device = device or jax.devices()[0]
        self.pool = SecureChannelPool(
            bridge, n_workers=max(1, pool_workers), clock=self.clock)
        self.stats = GatewayStats()
        self.records: list[CopyRecord] = []
        #: emit hooks: every finished crossing is pushed to each subscriber
        #: (trace.TraceRecorder attaches here to build a BridgeTape)
        self.on_record: list[Callable[[CopyRecord], None]] = []
        #: optional bridge_opt.StagingArena — when attached, staging is a
        #: budgeted slab resource instead of the unbounded registered set
        self.arena = arena
        #: optional resilience.FaultInjector — when attached (via its own
        #: ``attach``), every charged crossing routes through the injector's
        #: submit-path hook (brownout scaling, teardown, MAC-reject retries).
        #: None means the fault-free fast path: zero extra work, golden tapes
        #: unchanged.
        self.faults: Optional[Any] = None
        #: optional fabric.FabricTransport — when attached, ``p2p`` prices
        #: in-tenant device-to-device movement against the tenant's live
        #: fabric state (full P2P rate when healthy+attested, TCP fallback
        #: otherwise).  None means no fabric view: ``p2p`` assumes the
        #: profile's fabric is up (single-tenant bench paths).
        self.fabric: Optional[Any] = None
        self._staging_registered: set[tuple] = set()

    def _faulted_cost(self, op_class: str, crossing: Crossing, cost: float, *,
                      n_units: int = 1) -> float:
        """Route a charged crossing through the fault injector, if any."""
        if self.faults is None:
            return cost
        return self.faults.on_crossing(op_class, crossing, cost,
                                       n_units=n_units)

    # -- staging discipline -----------------------------------------------------------

    def _staging_kind(self, shape: tuple[int, ...], dtype: Any, nbytes: int, *,
                      reuse_staging: bool) -> tuple[StagingKind, tuple[str, ...]]:
        """Resolve a crossing's staging path; returns (kind, record tags).

        With a StagingArena attached, *every* crossing stages through the
        persistent slab pool — the arena replaces per-call fresh allocation
        even on the non-reuse (async) path, which is exactly the fix for
        the 44x class.  Without one, the legacy machine applies: FRESH on
        first sight of a (shape, dtype) buffer unless the caller drains and
        reuses staging (the sync/worker pattern); REGISTERED afterwards.
        Keying on (shape, dtype) — not shape alone — keeps two buffers of
        equal shape but different element width from sharing a slot.
        """
        if self.arena is not None:
            kind, tag = self.arena.acquire(nbytes)
            return kind, (tag,)
        key = (tuple(shape), str(dtype))
        if reuse_staging and key in self._staging_registered:
            return StagingKind.REGISTERED, ()
        if reuse_staging:
            self._staging_registered.add(key)
            return StagingKind.FRESH, ()  # first touch registers the slot
        return StagingKind.FRESH, ()

    # -- crossings ---------------------------------------------------------------------

    def h2d(self, host_array: np.ndarray, *, op_class: str = "h2d",
            reuse_staging: bool = True) -> jax.Array:
        """One host-to-device crossing: real device_put + bridge-law charge."""
        arr = np.asarray(host_array)
        staging, tags = self._staging_kind(arr.shape, arr.dtype, int(arr.nbytes),
                                           reuse_staging=reuse_staging)
        crossing = Crossing(int(arr.nbytes), Direction.H2D, staging)
        cost = self.bridge.crossing_time(crossing, n_contexts=self.pool.n_workers)
        cost = self._faulted_cost(op_class, crossing, cost)
        end = self.clock.advance(cost)
        self._record(crossing, cost, op_class, t_end=end, tags=tags)
        return jax.device_put(arr, self.device)

    def d2h(self, device_array: jax.Array, *, op_class: str = "d2h",
            tags: tuple = (), raw_bytes: int = 0,
            codec: str = "") -> np.ndarray:
        """One device-to-host crossing (the drain).  Blocking under CC (L2).

        Drain staging follows the same economics as uploads: with a
        StagingArena attached the bounce buffer is a budgeted slab (first
        touch of a size class pays the FRESH toll exactly once, then warm
        hits), so D2H first-touch is priced like H2D instead of assuming a
        pre-registered buffer the runtime never paid for.  Without an arena
        the legacy model applies — the engine owns one persistent output
        staging buffer, so drains stay REGISTERED.
        """
        nbytes = _nbytes(device_array)
        if self.arena is not None:
            staging, tag = self.arena.acquire(nbytes)
            tags = tuple(tags) + (tag,)
        else:
            staging = StagingKind.REGISTERED
        crossing = Crossing(nbytes, Direction.D2H, staging)
        cost = self.bridge.crossing_time(crossing, n_contexts=self.pool.n_workers)
        cost = self._faulted_cost(op_class, crossing, cost)
        end = self.clock.advance(cost)
        self._record(crossing, cost, op_class, t_end=end, tags=tags,
                     raw_bytes=raw_bytes, codec=codec)
        return np.asarray(device_array)

    def batch_h2d(self, host_arrays: Sequence[np.ndarray], *,
                  op_class: str = "batch_h2d") -> list[jax.Array]:
        """§8 rule 1: batch small crossings into one staged crossing.

        With batching enabled, N small arrays are packed into one staging
        buffer and pay ONE toll; without, each pays its own.
        """
        if not host_arrays:
            return []
        if not self.defaults.batch_small_crossings:
            # unbatched baseline still follows the staging discipline:
            # repeated (shape, dtype) buffers reuse registered staging
            # rather than paying FRESH per array per call, so comparing
            # against the batched path measures *batching*, not staging abuse
            return [self.h2d(a, op_class=op_class, reuse_staging=True)
                    for a in host_arrays]
        total = sum(_nbytes(a) for a in host_arrays)
        if self.arena is not None:
            staging, tag = self.arena.acquire(total)
            tags: tuple[str, ...] = (tag,)
        else:
            staging, tags = StagingKind.REGISTERED, ()
        crossing = Crossing(total, Direction.H2D, staging)
        cost = self.bridge.crossing_time(crossing, n_contexts=self.pool.n_workers)
        # one fused ciphertext: any constituent MAC reject re-pays the batch
        cost = self._faulted_cost(op_class, crossing, cost,
                                  n_units=len(host_arrays))
        end = self.clock.advance(cost)
        self._record(crossing, cost, op_class, t_end=end, tags=tags)
        self.stats.batched_crossings_saved += len(host_arrays) - 1
        return [jax.device_put(np.asarray(a), self.device) for a in host_arrays]

    def bulk_h2d_pooled(self, host_arrays: Sequence[np.ndarray], *,
                        op_class: str = "bulk_h2d", tags: tuple = (),
                        raw_bytes: Optional[Sequence[int]] = None,
                        codec: str = "") -> list[jax.Array]:
        """Bulk movement over the context pool (loader / KV restore path).

        ``raw_bytes`` (parallel to ``host_arrays``) marks quantized payloads:
        the arrays already hold *wire* bytes — the pool prices what crosses —
        while each record additionally carries the full-width byte count and
        codec id for the un-quantize replay counterfactual (DESIGN.md §13).
        """
        self.pool.ensure_ready()
        out = []
        before = self.clock.now
        for i, a in enumerate(host_arrays):
            crossing = Crossing(_nbytes(a), Direction.H2D, StagingKind.REGISTERED)
            ctx_id, start, done = self.pool.submit_ex(crossing)
            raw_i = raw_bytes[i] if raw_bytes else 0
            # per-crossing record carries its single-channel duration; the
            # wall-clock charge comes from the drain below
            self._record(crossing, done - start, op_class, charge=False,
                         channel=ctx_id, t_end=done, tags=tags,
                         raw_bytes=raw_i, codec=codec if raw_i else "")
            out.append(jax.device_put(np.asarray(a), self.device))
        self.pool.drain()
        self.stats.bridge_time_s += self.clock.now - before
        return out

    def pooled_crossing(self, crossing: Crossing, *, op_class: str,
                        tags: tuple = (), sources: tuple = (),
                        raw_bytes: int = 0,
                        codec: str = "") -> tuple[int, float, float]:
        """Submit one crossing to the channel pool, recorded *uncharged*.

        Returns ``(ctx_id, start, done)``.  The caller owns the
        critical-path charge — the pipelined KV restore uses this to block
        only for its pipeline fill while later chunks overlap engine work,
        and the worker-composed coalescer flushes its D2H queue here so the
        drain serializes on a worker channel instead of the engine clock.
        """
        ctx_id, start, done = self.pool.submit_ex(crossing)
        self._record(crossing, done - start, op_class, charge=False,
                     channel=ctx_id, t_end=done, tags=tags, sources=sources,
                     raw_bytes=raw_bytes, codec=codec)
        return ctx_id, start, done

    def charge_crossing(self, nbytes: int, direction: Direction, *,
                        staging: StagingKind = StagingKind.REGISTERED,
                        op_class: str, tags: tuple = (),
                        sources: tuple = (), raw_bytes: int = 0,
                        codec: str = "") -> float:
        """Price + record a metadata-only crossing (no tensor moves).

        Call sites that account a crossing without materializing its payload
        (the offload manager's metadata-only spill, the loader's modeled
        shard transfers, the coalescer's fused flushes) use this instead of
        hand-rolling stats so the crossing still lands in the tape with a
        consistent interval.
        """
        crossing = Crossing(int(nbytes), direction, staging)
        cost = self.bridge.crossing_time(crossing, n_contexts=self.pool.n_workers)
        # a coalesced flush is one ciphertext over len(sources) constituents
        cost = self._faulted_cost(op_class, crossing, cost,
                                  n_units=max(1, len(sources)))
        end = self.clock.advance(cost)
        self._record(crossing, cost, op_class, t_end=end, tags=tags,
                     sources=sources, raw_bytes=raw_bytes, codec=codec)
        return cost

    def record_modeled(self, nbytes: int, direction: Direction, cost: float, *,
                       op_class: str,
                       staging: StagingKind = StagingKind.REGISTERED,
                       tags: tuple = (), raw_bytes: int = 0,
                       codec: str = "") -> None:
        """Record a crossing whose cost an external model already computed.

        The pooled loader prices its ladder variants with its own calibrated
        component model (§6.1); this lets it charge that exact cost while the
        crossing still lands on the tape with direction/staging/bytes.  The
        charge always advances the clock — that is what keeps consecutive
        modeled crossings on non-overlapping intervals (L1/L2).
        """
        crossing = Crossing(int(nbytes), direction, staging)
        end = self.clock.advance(cost)
        self._record(crossing, cost, op_class, t_end=end, tags=tags,
                     raw_bytes=raw_bytes, codec=codec)

    # -- device-local compute ----------------------------------------------------------

    def charge_compute(self, seconds: float, *, op_class: str,
                       tags: tuple = (), bound: str = "") -> float:
        """Charge device-local compute (prefill/decode forward) to the clock.

        Compute is a first-class interval on the engine's virtual clock —
        without it the coalescer's deadline trigger never comes due and every
        overlap window is fictional.  The charge is NOT a crossing: nothing
        moves over the bridge, so it lands on the tape as a ``kind="compute"``
        record (direction/staging empty, channel -1 — the engine-serial path)
        and is counted in ``stats.compute_time_s``, never ``bridge_time_s``.
        Pricing belongs to the caller (core.compute.ComputeModel); so does
        ``bound`` ("compute"/"memory": which roofline term won — replay uses
        it to pick the matching CC parity factor when repricing).
        """
        if seconds < 0:
            raise ValueError(f"cannot charge negative compute {seconds}")
        end = self.clock.advance(seconds)
        self.stats.compute_charges += 1
        self.stats.compute_time_s += seconds
        rec = CopyRecord(
            op_class, 0, seconds, self.bridge.cc_on,
            direction="", staging="", channel=-1,
            t_start=end - seconds, t_end=end, charged=True,
            tags=tuple(tags), kind="compute", bound=bound)
        self.records.append(rec)
        for hook in self.on_record:
            hook(rec)
        return seconds

    # -- in-tenant fabric P2P (DESIGN.md §12) --------------------------------------------

    def p2p(self, nbytes: int, *, op_class: str, tags: tuple = (),
            extra_s: float = 0.0) -> float:
        """Charge an in-tenant fabric-P2P transfer (never the bridge).

        P2P is the one data path CC does not serialize: no host staging, no
        per-channel queueing, no toll floors — just bytes over the tenant
        fabric at ``fabric.p2p_bandwidth``.  The charge still advances the
        engine's virtual clock (a TP allreduce is on the step critical path)
        but lands on the tape as a ``kind="p2p"`` record on channel -1 with
        empty staging, counted in ``stats.p2p_*`` — never in
        ``bridge_time_s`` or the h2d/d2h crossing stats.

        The fabric decision is re-evaluated per call: a tenant whose
        partition went STALE or whose attestation evidence lapsed is priced
        at the CC-compatible TCP fallback rate and tagged FABRIC_FALLBACK,
        so degradation shows up in the tape as a pricing step, not a hidden
        slowdown.

        ``extra_s`` adds straggler time on top of the bandwidth term — the
        per-device clock-skew spread a ring collective waits out
        (``ComputeModel.allreduce_skew_s``).  Zero by default, so skew-free
        tapes (all goldens) are byte-identical to before.
        """
        from .fabric import FabricTransport, p2p_bandwidth
        if nbytes < 0:
            raise ValueError(f"cannot move negative bytes {nbytes}")
        if extra_s < 0:
            raise ValueError(f"cannot add negative straggler time {extra_s}")
        transport = self.fabric or FabricTransport(self.bridge.profile)
        up = transport.fabric_up()
        bw = p2p_bandwidth(self.bridge.profile, fabric_up=up)
        cost = (nbytes / bw if nbytes else 0.0) + extra_s
        if not up:
            tags = tuple(tags) + ("fabric_fallback",)
            self.stats.p2p_fallback_crossings += 1
        end = self.clock.advance(cost)
        self.stats.p2p_crossings += 1
        self.stats.p2p_bytes += int(nbytes)
        self.stats.p2p_time_s += cost
        rec = CopyRecord(
            op_class, int(nbytes), cost, self.bridge.cc_on,
            direction=Direction.P2P.value, staging="", channel=P2P_CHANNEL,
            t_start=end - cost, t_end=end, charged=True,
            tags=tuple(tags), kind="p2p")
        self.records.append(rec)
        for hook in self.on_record:
            hook(rec)
        return cost

    # -- bookkeeping -------------------------------------------------------------------

    def _record(self, crossing: Crossing, cost: float, op_class: str, *,
                charge: bool = True, channel: int = -1,
                t_end: Optional[float] = None, tags: tuple = (),
                sources: tuple = (), raw_bytes: int = 0,
                codec: str = "") -> None:
        """`charge=False` keeps the per-crossing duration in the records (for
        op-class attribution) without adding it to bridge_time_s — used when
        the wall-clock charge is accounted elsewhere (pooled drain).

        `channel` is the secure-context id the crossing serialized on (-1 for
        the engine-serial path); `t_end` overrides the completion timestamp
        for pool-scheduled crossings whose interval the pool computed.
        """
        if crossing.direction is Direction.H2D:
            self.stats.h2d_crossings += 1
            self.stats.h2d_bytes += crossing.nbytes
        else:
            self.stats.d2h_crossings += 1
            self.stats.d2h_bytes += crossing.nbytes
        if charge:
            self.stats.bridge_time_s += cost
        end = self.clock.now if t_end is None else t_end
        rec = CopyRecord(
            op_class, crossing.nbytes, cost, self.bridge.cc_on,
            direction=crossing.direction.value, staging=crossing.staging.value,
            channel=channel, t_start=end - cost, t_end=end, charged=charge,
            tags=tuple(tags), sources=tuple(sources),
            raw_bytes=int(raw_bytes), codec=codec)
        self.records.append(rec)
        for hook in self.on_record:
            hook(rec)
