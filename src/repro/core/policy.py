"""Scheduling policies and CC-mode-aware defaults (paper §5, §8 rule 3).

The paper's central serving result is *policy inversion*: vLLM's default
async scheduling — overlap step N's device-to-host output drain with step
N+1's preparation — saves ~3 ms/step without CC and costs ~4 ms/step with it,
because the overlapped copies serialize anyway (bridge law L1/L2) while the
stream-arbitration overhead remains.

This module defines the policy vocabulary used across the engine, the
simulator and the benchmarks, and the CC-aware default selection the paper
says belongs in the runtime ("Runtimes should detect GPU-CC mode and flip
scheduling, offload, and streaming defaults accordingly").
"""

from __future__ import annotations

import enum
import os
from dataclasses import dataclass, field
from typing import Optional

from .bridge import BridgeModel, BridgeProfile


class SchedulingPolicy(enum.Enum):
    #: vLLM default: overlap step-N output drain with step-N+1 prep on extra
    #: CUDA streams.  Optimal CC-off; *inverted* (harmful) CC-on.
    ASYNC_OVERLAP = "async"
    #: --no-async-scheduling: forward, sample, one small D2H, drain, continue.
    #: The drained, sequential pattern the secure bridge is engineered for.
    SYNC_DRAIN = "sync"
    #: v10c: keep async structure but move the *blocking* drain to a worker
    #: thread.  A blocked crossing releases the GIL, so the engine thread
    #: pipelines host work while the worker sits in the driver.
    WORKER_DRAIN = "worker"


class OffloadPolicy(enum.Enum):
    #: default vLLM CPU-offload: spill every evicted block (2.3 GiB measured)
    SPILL_ALL = "spill_all"
    #: reuse-aware: offload only blocks observed >= store_threshold times
    #: (2.3 MB measured; 2.97x warm-TTFT improvement CC-on)
    REUSE_AWARE = "reuse_aware"
    #: residency-first: never offload (buy residency — §8 rule 4)
    NO_OFFLOAD = "no_offload"


def overlap_scheduler_default() -> bool:
    """CI matrix hook: REPRO_OVERLAP_SCHEDULER=0 turns the overlap
    *preference* off fleet-wide (tier-1 must pass either way — the barrier
    semantics are not optional).  This is the single source of truth:
    every RuntimeDefaults construction honors it unless a caller overrides
    the field explicitly."""
    return os.environ.get("REPRO_OVERLAP_SCHEDULER", "1") != "0"


def observability_default() -> bool:
    """REPRO_OBS=0 disables observatory creation fleet-wide (the obs layer
    is passive — it never moves the virtual clock — so this is purely a
    host-overhead lever; bench_obs measures the on/off ratio and CI bounds
    it at 1.10x)."""
    return os.environ.get("REPRO_OBS", "1") != "0"


@dataclass(frozen=True)
class RuntimeDefaults:
    """Policy defaults the runtime should select for a given CC mode."""

    scheduling: SchedulingPolicy
    offload: OffloadPolicy
    store_threshold: int
    #: loader worker contexts (0 = single-context default path)
    loader_pool_workers: int
    loader_prewarm: bool
    #: batch small per-step crossings into one staged crossing (§8 rule 1)
    batch_small_crossings: bool
    # ---- bridge_opt levers (DESIGN.md §6) -------------------------------------
    #: pinned-byte budget for the persistent StagingArena (0 = legacy
    #: unbudgeted registered-set staging, no arena)
    staging_arena_bytes: int = 0
    #: queue sub-threshold crossings and flush them fused (CrossingCoalescer)
    coalesce_small_crossings: bool = False
    #: chunk + double-buffer KV restores across the channel pool so restore
    #: overlaps subsequent decode steps (attacks the +131% restore penalty)
    pipelined_restore: bool = False
    # ---- compute-charged clock + overlap scheduling (DESIGN.md §7) ------------
    #: charge per-step prefill/decode compute to the virtual clock (the
    #: ComputeModel roofline) — what makes coalescer deadlines come due and
    #: restore-overlap windows real
    charge_compute: bool = True
    #: prefer scheduling decode compute into windows where pipelined-restore
    #: channels are busy past clock.now (restored admissions defer while
    #: other decode work fills the window).  The restore_barrier correctness
    #: edge is ALWAYS enforced; this flag only controls the preference.
    overlap_scheduler: bool = field(default_factory=overlap_scheduler_default)
    # ---- slot-masked decode (DESIGN.md §8) ------------------------------------
    #: step only the slots whose KV restores have landed (slot-granular read
    #: sets) instead of barriering the whole decode batch on any one slot's
    #: pending restore.  Inert without late restores in flight — with no
    #: pending restore the masked path is byte-identical to the fused batch
    #: step, which is what keeps the golden tapes stable with the flag on.
    slot_masked_decode: bool = True
    # ---- packed ragged decode (DESIGN.md §10) ---------------------------------
    #: execute decode over a packed (non-padded) batch of exactly the ready
    #: slots instead of a dense batch padded to max_batch: prep crossings,
    #: the drain and the compute charge all cover the packed set, so a
    #: half-empty engine stops paying full-batch bridge bytes and phantom
    #: lanes.  Token streams are byte-identical to the dense/slot-masked
    #: paths under greedy decode (rows are batch-independent); with the flag
    #: off the engine takes the legacy dense step.  Packing is what lets
    #: max_batch climb into the hundreds–thousands without every step
    #: paying the widest slot set.
    packed_decode: bool = True
    # ---- observability (DESIGN.md §9) ------------------------------------------
    #: create a repro.obs.Observatory for engines/replicas that are not
    #: handed one explicitly (metrics registry + request spans wired into
    #: the gateway's record stream).  Passive: never touches the clock.
    observability: bool = field(default_factory=observability_default)
    # ---- quantized bridge crossings (DESIGN.md §13) -----------------------------
    #: codec for KV offload/restore crossings ("fp8" | "int8"; "" = full
    #: width).  Spills and restores move wire bytes; restore pays a
    #: dequant compute charge (never bridge time).
    kv_quant: str = ""
    #: codec for weight shard uploads (the 34x load path at 1/2–1/4 bytes)
    weight_quant: str = ""
    #: max per-block relative round-trip error a selected codec may show on
    #: the seeded probe — quant.select_codec refuses codecs above it (e.g.
    #: 0.01 accepts int8, refuses fp8-e4m3)
    accuracy_budget: float = 0.05


def cc_aware_defaults(cc_on: bool, *, allow_worker_drain: bool = True,
                      concurrency: Optional[int] = None,
                      bridge_opt: bool = False) -> RuntimeDefaults:
    """The paper's §8 checklist as a runtime default table.

    CC-off: the classic overlap-everything defaults are correct.
    CC-on : flip scheduling (inversion), make offload evidence-driven, pool
            loader contexts, and batch small crossings.

    Beyond-paper refinement: WORKER_DRAIN's per-step wake overhead only
    amortizes at high concurrency (its measured win is at c=512; at c=128 it
    barely beats sync), so the default is concurrency-aware — SYNC_DRAIN
    below 256 concurrent sequences, WORKER_DRAIN above.  `allow_worker_drain`
    gates the qualified v10c patch entirely; the conservative default is the
    fully-reproduced one-flag fix (SYNC_DRAIN).

    `bridge_opt=True` additionally enables the transfer-optimization
    subsystem (persistent staging arena, crossing coalescer, pipelined KV
    restore — DESIGN.md §6) when CC is on.  It is off by default so the
    paper's measured baselines stay reproducible as recorded.
    """
    if not cc_on:
        return RuntimeDefaults(
            scheduling=SchedulingPolicy.ASYNC_OVERLAP,
            offload=OffloadPolicy.SPILL_ALL,
            store_threshold=0,
            loader_pool_workers=0,
            loader_prewarm=False,
            batch_small_crossings=False,
        )
    use_worker = allow_worker_drain and (concurrency is None or concurrency >= 256)
    return RuntimeDefaults(
        scheduling=(SchedulingPolicy.WORKER_DRAIN if use_worker
                    else SchedulingPolicy.SYNC_DRAIN),
        offload=OffloadPolicy.REUSE_AWARE,
        store_threshold=2,
        loader_pool_workers=8,
        loader_prewarm=True,
        batch_small_crossings=True,
        staging_arena_bytes=(64 << 20) if bridge_opt else 0,
        coalesce_small_crossings=bridge_opt,
        pipelined_restore=bridge_opt,
    )


@dataclass
class PolicyOutcome:
    """One (policy, cc_mode) measurement used by the inversion detector."""

    policy: SchedulingPolicy
    cc_on: bool
    tokens_per_s: float


def detect_inversion(outcomes: list[PolicyOutcome]) -> dict[str, object]:
    """Detect policy inversion from measured/simulated outcomes.

    Inversion (the Blackwell result): the policy ordering flips with CC —
    async > sync CC-off but async < sync CC-on.  Neutralization (the Hopper
    boundary result): async's benefit disappears but does not become a loss.
    """

    def best(cc_on: bool) -> Optional[PolicyOutcome]:
        cands = [o for o in outcomes if o.cc_on is cc_on]
        return max(cands, key=lambda o: o.tokens_per_s) if cands else None

    def get(policy: SchedulingPolicy, cc_on: bool) -> Optional[PolicyOutcome]:
        for o in outcomes:
            if o.policy is policy and o.cc_on is cc_on:
                return o
        return None

    a_off, s_off = get(SchedulingPolicy.ASYNC_OVERLAP, False), get(SchedulingPolicy.SYNC_DRAIN, False)
    a_on, s_on = get(SchedulingPolicy.ASYNC_OVERLAP, True), get(SchedulingPolicy.SYNC_DRAIN, True)
    if None in (a_off, s_off, a_on, s_on):
        raise ValueError("need async/sync outcomes for both CC modes")

    async_gain_off = (a_off.tokens_per_s - s_off.tokens_per_s) / s_off.tokens_per_s
    async_gain_on = (a_on.tokens_per_s - s_on.tokens_per_s) / s_on.tokens_per_s
    # classification thresholds: 1% band counts as a tie (paper's H200 case)
    inverted = async_gain_off > 0.01 and async_gain_on < -0.01
    neutralized = async_gain_off > 0.01 and abs(async_gain_on) <= 0.01
    return {
        "async_gain_cc_off": async_gain_off,
        "async_gain_cc_on": async_gain_on,
        "inverted": inverted,
        "neutralized": neutralized,
        "best_cc_off": best(False).policy,
        "best_cc_on": best(True).policy,
    }


def recovered_fraction(cc_default: float, cc_fixed: float, gold: float) -> float:
    """Fraction of the CC gap a fix recovers: (fixed - default) / (gold - default)."""
    gap = gold - cc_default
    if gap <= 0:
        return 1.0
    return (cc_fixed - cc_default) / gap
