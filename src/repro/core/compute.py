"""ComputeModel — per-step prefill/decode compute as a virtual-clock charge.

The paper's recovery story (§5.4–§5.5) only exists because decode compute
gives the bridge a window to hide crossings in: the scheduling flag recovers
57% and the worker-thread drain up to 92% *of a gap measured against steps
that spend most of their time in the forward pass*.  The Hopper CC benchmark
study (arXiv 2409.03992) makes the same point from the other side — whether
CC overhead is hideable is exactly the compute/crossing ratio.  A simulator
that charges crossings but not compute therefore cannot say anything about
recovery: its coalescing deadlines never come due and its restore-overlap
windows are fictional.

This module prices one engine step's compute the same way the repo prices
one crossing: a small analytic model over quantities the engine already has
(the ``ModelConfig`` shapes), evaluated against a per-platform roofline
(peak FLOPs + HBM bandwidth) with the CC parity factors the bridge law
already encodes (``BridgeModel.compute_time`` / ``hbm_time`` — device-local
work is at parity, L5).  Decode is weight-read memory-bound for every
serving-scale config; prefill is FLOPs-bound for long prompts.  The charges
land on the tape as ``kind="compute"`` records (see trace/tape.py), so
replay attribution and the conformance checker see the full step anatomy,
not just its crossings.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig

from .bridge import BridgeModel


@dataclass(frozen=True)
class ComputeSpec:
    """Device roofline constants (FLOPs/s dense, bytes/s HBM)."""

    peak_flops: float
    hbm_bw: float


#: per-bridge-profile rooflines.  TPU v5e matches launch/dryrun.PEAK_FLOPS;
#: the GPU entries are the platforms' public dense-BF16 / HBM figures — the
#: law-level claims (parity, hideability ordering) do not depend on their
#: exact values, only on compute being charged at all.
COMPUTE_SPECS = {
    "b300-hgx": ComputeSpec(peak_flops=2.25e15, hbm_bw=8.0e12),
    "rtx-pro-6000": ComputeSpec(peak_flops=2.5e14, hbm_bw=1.8e12),
    "h200": ComputeSpec(peak_flops=9.9e14, hbm_bw=4.8e12),
    "tpu-v5e": ComputeSpec(peak_flops=197e12, hbm_bw=819e9),
}

DEFAULT_SPEC = COMPUTE_SPECS["tpu-v5e"]


def spec_for_profile(profile_name: str) -> ComputeSpec:
    """Roofline for a bridge profile — unknown names are an error.

    The silent historical fallback (unknown -> TPU v5e) mispriced every
    charge on an unrecognized platform by ~10x without a word, which
    corrupts exactly the compute/crossing ratio the recovery numbers are
    measured against.  A caller with a platform we have no roofline for
    must say what it costs (``ComputeModel(..., spec=...)``).
    """
    try:
        return COMPUTE_SPECS[profile_name]
    except KeyError:
        known = ", ".join(sorted(COMPUTE_SPECS))
        raise ValueError(
            f"no ComputeSpec for bridge profile {profile_name!r} "
            f"(known: {known}); pass spec= explicitly") from None


def _dtype_bytes(dtype) -> int:
    try:
        return int(np.dtype(dtype).itemsize)
    except TypeError:
        return 2  # bf16-class default


@dataclass(frozen=True)
class ComputeCharge:
    """One priced unit of device compute (what the gateway charges)."""

    kind: str             # "prefill" | "decode"
    flops: float
    hbm_bytes: float
    seconds: float
    bound: str            # "compute" | "memory" — which roofline term won

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)


class ComputeModel:
    """Roofline pricing of engine steps against the active bridge profile.

    Pure and deterministic, like ``BridgeModel``: the engine (or a
    benchmark) asks for a step's seconds and charges them through
    ``TransferGateway.charge_compute``.  The model and the executed network
    are deliberately decoupled — benchmarks run the tiny smoke model for
    token correctness while pricing compute against the paper's 27B serving
    config, exactly as the crossing side prices B300 tolls on CPU.
    """

    def __init__(self, cfg: ModelConfig, bridge: BridgeModel, *,
                 spec: Optional[ComputeSpec] = None, tp_degree: int = 1,
                 skew: Optional[Sequence[float]] = None):
        if tp_degree < 1:
            raise ValueError(f"tp_degree must be >= 1, got {tp_degree}")
        self.cfg = cfg
        self.bridge = bridge
        self.spec = spec if spec is not None else spec_for_profile(
            bridge.profile.name)
        self.active_params = float(cfg.active_param_count())
        self.bytes_per_param = _dtype_bytes(cfg.dtype)
        #: tensor-parallel degree (DESIGN.md §12): per-device FLOPs and HBM
        #: traffic divide by it (weights, KV and activations are sharded
        #: across the tenant's partition), and each decode/prefill step owes
        #: a ring allreduce over the tenant fabric — priced separately by
        #: ``allreduce_seconds`` and charged by the engine as a
        #: ``p2p_allreduce`` record, never folded into the compute interval.
        self.tp_degree = int(tp_degree)
        #: per-device clock skew within the TP group, seconds (one entry per
        #: device).  A ring collective completes when its slowest member
        #: arrives, so each step's allreduce waits out the skew *spread*
        #: (max - min) on top of the bandwidth term — stragglers become
        #: priceable instead of invisible.  None/zero vector = no skew, and
        #: the surcharge is exactly 0.0, so skew-free tapes (all goldens)
        #: are unchanged.
        if skew is not None:
            skew = tuple(float(s) for s in skew)
            if len(skew) != self.tp_degree:
                raise ValueError(
                    f"skew vector has {len(skew)} entries for "
                    f"tp_degree={self.tp_degree}")
            if any(s < 0 for s in skew):
                raise ValueError(f"skew entries must be >= 0, got {skew}")
        self.skew = skew

    # -- per-token byte/flop terms ------------------------------------------------------

    def kv_bytes_per_token(self) -> float:
        """KV-cache bytes one cached token contributes per decode step."""
        if self.cfg.is_attention_free:
            # SSM state is O(1) in sequence length; fold it into weights
            return 0.0
        per_layer = 2 * self.cfg.n_kv_heads * self.cfg.head_dim * self.bytes_per_param
        return float(per_layer * self.cfg.n_layers)

    # -- decode -------------------------------------------------------------------------

    def decode_charge(self, batch: int, *, kv_len: float = 0.0) -> ComputeCharge:
        """One batched decode step: every active param touched once (weight
        reads dominate), plus the KV read for each sequence's cached prefix.

        An empty batch charges zero seconds: the engine's nothing-ready path
        takes the pipeline barrier, never a forward — the old
        ``max(1, batch)`` clamp billed one slot's FLOPs *and* the full
        weight stream for a step that never ran.
        """
        batch = max(0, int(batch))
        if batch == 0:
            return ComputeCharge("decode", 0.0, 0.0, 0.0, "compute")
        flops = 2.0 * self.active_params * batch
        hbm = (self.active_params * self.bytes_per_param
               + batch * max(0.0, kv_len) * self.kv_bytes_per_token())
        return self._charge("decode", flops, hbm)

    def decode_step_s(self, batch: int, *, kv_len: float = 0.0) -> float:
        return self.decode_charge(batch, kv_len=kv_len).seconds

    # -- masked decode (slot-masked execution; DESIGN.md §8) ----------------------------

    def decode_charge_masked(self, kv_lens: "Sequence[float]") -> ComputeCharge:
        """One slot-masked decode step priced for exactly the ready slots.

        The engine steps only slots whose KV restores have landed; deferred
        slots stay resident but contribute neither FLOPs nor KV reads this
        step.  The weight-read term is batch-independent (every active param
        streams once per step regardless of how many slots consume it), so a
        masked step is cheaper than the full batch only by the deferred
        slots' FLOPs and KV traffic — which is exactly the charge the
        coalescer deadlines and restore-overlap windows must see, or the
        clock would bill deferred work that never ran.  Per-slot ``kv_lens``
        (not a batch mean) because the ready set's prefix lengths are known.

        An empty ready set charges zero (see ``decode_charge``): zero ready
        slots means no forward ran, so billing one phantom slot — as the
        old ``max(1, len(kv_lens))`` did — charged a full weight stream for
        nothing.
        """
        ready = len(kv_lens)
        if ready == 0:
            return ComputeCharge("decode", 0.0, 0.0, 0.0, "compute")
        flops = 2.0 * self.active_params * ready
        hbm = (self.active_params * self.bytes_per_param
               + sum(max(0.0, k) for k in kv_lens) * self.kv_bytes_per_token())
        return self._charge("decode", flops, hbm)

    def decode_step_masked_s(self, kv_lens: "Sequence[float]") -> float:
        return self.decode_charge_masked(kv_lens).seconds

    # -- packed ragged decode (DESIGN.md §10) -------------------------------------------

    def decode_charge_packed(self, kv_lens: "Sequence[float]") -> ComputeCharge:
        """One packed ragged decode step priced for the packed set.

        Packing is an *execution* change — the forward runs over exactly the
        packed rows instead of a dense batch padded to the widest slot set —
        not a pricing change: the packed set's charge is identical to a
        slot-masked step over the same per-slot KV lengths (weights stream
        once regardless of how the rows are laid out; KV traffic sums the
        packed prefixes).  Kept as its own entry point so the engine's
        DECODE_PACKED records and the parity property
        (``packed == masked == dense`` for equal lengths) both have a named
        subject, and so a future paged-attention packed kernel can diverge
        here without touching the masked path.
        """
        return self.decode_charge_masked(kv_lens)

    def decode_step_packed_s(self, kv_lens: "Sequence[float]") -> float:
        return self.decode_charge_packed(kv_lens).seconds

    # -- prefill ------------------------------------------------------------------------

    def prefill_charge(self, tokens: int) -> ComputeCharge:
        """Prompt processing for ``tokens`` new tokens (restored/warm tokens
        are the caller's to exclude — they skip the forward entirely)."""
        tokens = max(0, int(tokens))
        if tokens == 0:
            return ComputeCharge("prefill", 0.0, 0.0, 0.0, "compute")
        flops = 2.0 * self.active_params * tokens
        hbm = (self.active_params * self.bytes_per_param
               + tokens * self.kv_bytes_per_token())
        return self._charge("prefill", flops, hbm)

    def prefill_s(self, tokens: int) -> float:
        return self.prefill_charge(tokens).seconds

    # -- tensor-parallel allreduce (DESIGN.md §12) --------------------------------------

    def allreduce_bytes(self, batch: int) -> int:
        """Per-device wire bytes of one step's TP ring allreduces.

        A TP transformer layer allreduces twice (attention output + MLP
        output), each over the step's activations (batch x d_model).  A ring
        over ``tp_degree`` devices moves ``2 (tp-1)/tp`` x payload per
        device (reduce-scatter + all-gather).  Zero when tp == 1 (nothing to
        reduce) or the batch is empty (no forward ran — the phantom-charge
        rule applies to collectives too).
        """
        batch = max(0, int(batch))
        if self.tp_degree == 1 or batch == 0:
            return 0
        payload = 2 * self.cfg.n_layers * batch * self.cfg.d_model * self.bytes_per_param
        return int(2 * (self.tp_degree - 1) / self.tp_degree * payload)

    def allreduce_skew_s(self) -> float:
        """Straggler wait of one ring collective: the skew *spread* (max -
        min) across the TP group — the fastest device idles until the
        slowest arrives.  0.0 without a skew vector or below tp=2."""
        if not self.skew or self.tp_degree == 1:
            return 0.0
        return max(self.skew) - min(self.skew)

    def allreduce_seconds(self, batch: int, p2p_bw: float) -> float:
        """One step's allreduce time over the tenant fabric at ``p2p_bw``,
        plus the straggler wait when a skew vector is set."""
        nbytes = self.allreduce_bytes(batch)
        if nbytes == 0:
            return 0.0
        return nbytes / p2p_bw + self.allreduce_skew_s()

    # -- dequantization (quantized crossings; DESIGN.md §13) ----------------------------

    def dequant_charge(self, raw_bytes: int, wire_bytes: int) -> ComputeCharge:
        """On-device widening of a quantized payload after a wire-priced
        restore (the ``kernels/dequant`` pass): read the codes + scales
        (``wire_bytes``), write full width (``raw_bytes``), ~2 flops per
        emitted value (decode + scale multiply).  Memory-bound by
        construction — its arithmetic intensity is ~2 flops per 3 bytes —
        which is the point: the bytes the bridge didn't move are paid for
        in HBM stream time, never hidden.  Zero raw bytes charge nothing
        (the phantom-charge rule)."""
        raw = max(0, int(raw_bytes))
        wire = max(0, int(wire_bytes))
        if raw == 0:
            return ComputeCharge("dequant", 0.0, 0.0, 0.0, "compute")
        flops = 2.0 * wire  # one code byte per value
        hbm = float(wire + raw)
        return self._charge("dequant", flops, hbm)

    def dequant_s(self, raw_bytes: int, wire_bytes: int) -> float:
        return self.dequant_charge(raw_bytes, wire_bytes).seconds

    # -- the roofline -------------------------------------------------------------------

    def _charge(self, kind: str, flops: float, hbm_bytes: float) -> ComputeCharge:
        """Per-device roofline: under TP the weights, KV and activations are
        sharded, so one device sees 1/tp of the step's FLOPs and HBM bytes
        (the allreduce that glues the shards back together is priced
        separately — it is fabric traffic, not device compute)."""
        flops /= self.tp_degree
        hbm_bytes /= self.tp_degree
        ct = self.bridge.compute_time(flops, self.spec.peak_flops)
        mt = self.bridge.hbm_time(hbm_bytes, self.spec.hbm_bw)
        if ct >= mt:
            return ComputeCharge(kind, flops, hbm_bytes, ct, "compute")
        return ComputeCharge(kind, flops, hbm_bytes, mt, "memory")
