"""Sharded checkpointing with atomic manifests, async commit, and elastic
resharding (restore onto any mesh shape).

Layout:
  <dir>/step_<N>/
      manifest.json        # leaf paths, shapes, dtypes, step, wall time
      <leaf-key>.bin       # raw little-endian bytes per leaf
      COMMITTED            # written last — a step without it is incomplete

Fault-tolerance contract: `restore_latest` scans for the newest *committed*
step, so a crash mid-save can never be resumed from.  `save(async_commit=
True)` runs serialization on a worker thread — the training loop keeps
stepping while bytes land (the paper's "keep crossings off the critical
path", applied to checkpoint traffic).

Elastic resharding: leaves are stored unsharded; `restore_latest` places
them with whatever shardings the *current* params template carries, so a
checkpoint from a (16,16) mesh restores onto (2,16,16), (8,8) or a single
host without conversion (launch/elastic.py drives this).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

try:
    import ml_dtypes  # registers bfloat16 etc. with numpy
except ImportError:  # pragma: no cover
    ml_dtypes = None

_PENDING: list[threading.Thread] = []


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def _dtype_name(x) -> str:
    return str(x.dtype)


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def save(ckpt_dir: str, params, opt_state, step: int, *,
         async_commit: bool = False) -> str:
    """Write a checkpoint; returns the step directory path."""
    state = {"params": params, "opt": opt_state}
    # snapshot to host (so donated/updated buffers can't race the writer)
    host = jax.tree.map(lambda x: np.asarray(x), state)

    def _write():
        d = os.path.join(ckpt_dir, f"step_{step}")
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for key, leaf in _leaf_paths(host):
            fname = key.replace("/", "__") + ".bin"
            arr = np.asarray(leaf)
            with open(os.path.join(tmp, fname), "wb") as f:
                f.write(arr.tobytes())
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": _dtype_name(arr)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        with open(os.path.join(d, "COMMITTED"), "w") as f:
            f.write(str(step))
        return d

    if async_commit:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        _PENDING.append(t)
        return os.path.join(ckpt_dir, f"step_{step}")
    return _write()


def wait_for_pending() -> None:
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def committed_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "COMMITTED")):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def _load_tree(d: str, template, manifest, prefix: str):
    """Rebuild a pytree from stored leaves, placed per the template's sharding."""
    leaves_meta = manifest["leaves"]

    def place(key_leaf):
        key, leaf = key_leaf
        meta = leaves_meta[f"{prefix}/{key}" if key else prefix]
        with open(os.path.join(d, meta["file"]), "rb") as f:
            arr = np.frombuffer(f.read(), dtype=_np_dtype(meta["dtype"])).reshape(meta["shape"])
        if hasattr(leaf, "sharding") and leaf.sharding is not None:
            try:
                return jax.device_put(arr, leaf.sharding)
            except Exception:
                pass
        return jnp.asarray(arr)

    keyed = _leaf_paths(template)
    placed = [place(kl) for kl in keyed]
    return jax.tree.unflatten(jax.tree.structure(template), placed)


def restore(ckpt_dir: str, step: int, params_template,
            opt_template: Optional[Any] = None):
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    params = _load_tree(d, params_template, manifest, "params")
    opt = None
    if opt_template is not None:
        opt = _load_tree(d, opt_template, manifest, "opt")
    else:
        # rebuild opt tree directly from the manifest (shape-driven)
        opt = _manifest_subtree(d, manifest, "opt")
    return params, opt, manifest["step"]


def _manifest_subtree(d: str, manifest, prefix: str):
    """Reconstruct a nested dict for all leaves under `prefix`."""
    root: dict = {}
    for key, meta in manifest["leaves"].items():
        if not key.startswith(prefix + "/") and key != prefix:
            continue
        with open(os.path.join(d, meta["file"]), "rb") as f:
            arr = np.frombuffer(f.read(), dtype=_np_dtype(meta["dtype"])).reshape(meta["shape"])
        parts = key[len(prefix) + 1:].split("/") if key != prefix else []
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        if parts:
            node[parts[-1]] = jnp.asarray(arr)
        else:
            return jnp.asarray(arr)
    return root


def restore_latest(ckpt_dir: str, params_template,
                   opt_template: Optional[Any] = None):
    steps = committed_steps(ckpt_dir)
    if not steps:
        return None
    return restore(ckpt_dir, steps[-1], params_template, opt_template)
