"""Optimizer: AdamW with global-norm clipping, plus distributed-optimization
hooks — int8 gradient compression with error feedback for the DP all-reduce.

Pure JAX, pytree-native (no optax dependency in this offline container).
Param leaves are layers.make_param dicts ({"value", "axes"}); optimizer state
mirrors the value tree and inherits the same shardings (FSDP-friendly).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


from repro.models.layers import Param, is_param as _is_param


def param_values(params):
    return jax.tree.map(lambda p: p.value, params, is_leaf=_is_param)


def with_values(params, values):
    flat_p = jax.tree.leaves(params, is_leaf=_is_param)
    flat_v = jax.tree.leaves(values)
    rebuilt = [Param(v, p.axes) for p, v in zip(flat_p, flat_v)]
    return jax.tree.unflatten(jax.tree.structure(params, is_leaf=_is_param), rebuilt)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    #: int8 gradient compression with error feedback for the DP all-reduce
    compress_grads: bool = False


def lr_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def init_opt_state(params) -> dict:
    zeros = jax.tree.map(lambda v: jnp.zeros(v.shape, F32), param_values(params))
    state = {"mu": zeros, "nu": jax.tree.map(jnp.copy, zeros), "step": jnp.zeros((), jnp.int32)}
    return state


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


# ---------------------------------------------------------------------------------
# Gradient compression (int8 + error feedback) — §8's "crossings are taxed"
# applied to the DP all-reduce: 4x fewer bytes on the wire, with the residual
# carried to the next step so convergence is preserved.
# ---------------------------------------------------------------------------------

def compress_int8(g: jax.Array, err: Optional[jax.Array]):
    gf = g.astype(F32) + (err if err is not None else 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(F32) * scale
    new_err = gf - deq
    return deq, new_err


def maybe_compress(grads, err_state, enabled: bool):
    if not enabled:
        return grads, err_state
    if err_state is None:
        err_state = jax.tree.map(lambda g: jnp.zeros(g.shape, F32), grads)
    pairs = jax.tree.map(compress_int8, grads, err_state)
    deq = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


# ---------------------------------------------------------------------------------
# Update
# ---------------------------------------------------------------------------------

def adamw_update(cfg: AdamWConfig, params, grads_values, state):
    """One AdamW step.  grads_values mirrors param_values(params)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)

    gnorm = global_norm(grads_values)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    grads_values = jax.tree.map(lambda g: g.astype(F32) * scale, grads_values)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads_values)
    nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g, state["nu"], grads_values)
    mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** step), mu)
    nu_hat = jax.tree.map(lambda n: n / (1 - b2 ** step), nu)

    values = param_values(params)
    new_values = jax.tree.map(
        lambda v, m, n: (v.astype(F32)
                         - lr * (m / (jnp.sqrt(n) + cfg.eps) + cfg.weight_decay * v.astype(F32))
                         ).astype(v.dtype),
        values, mu_hat, nu_hat)
    new_params = with_values(params, new_values)
    return new_params, {"mu": mu, "nu": nu, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
