"""Training step + loop with microbatching, fault tolerance, and the
distributed-optimization knobs (remat policy, gradient compression, donated
buffers).

`make_train_step(cfg, opt_cfg)` returns the pure function the launcher
pjit-compiles; `TrainLoop` adds checkpoint/restart and straggler accounting
around it for the end-to-end example drivers.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model import loss_fn
from .optimizer import (AdamWConfig, adamw_update, init_opt_state,
                        maybe_compress, param_values)

F32 = jnp.float32


def make_train_step(cfg, opt_cfg: AdamWConfig, *, microbatches: int = 1,
                    grad_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    Microbatching: the global batch splits along axis 0 into `microbatches`
    sequential grad accumulations (activation-memory control at large shapes).
    Gradient compression (int8 + error feedback) applies at the accumulation
    boundary — i.e. on what would cross the DP all-reduce.

    grad_shardings: optional pytree of NamedShardings (matching
    param_values(params)).  Constraining gradients to the FSDP layout right
    at the autodiff boundary makes GSPMD produce them via reduce-scatter
    instead of all-reduce + slice — 2x fewer bytes on the wire for
    data-sharded params (EXPERIMENTS.md §Perf, nemotron iteration B3).
    """

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b), has_aux=True)

    def _constrain(grads_values):
        if grad_shardings is None:
            return grads_values
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads_values, grad_shardings)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = _constrain(param_values(grads))
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb)
                g = param_values(g)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(F32), g_acc, g)
                return (g_acc, l_acc + l), m

            zeros = jax.tree.map(lambda v: jnp.zeros(v.shape, F32),
                                 param_values(params))
            (grads, loss_sum), ms = jax.lax.scan(acc_step, (zeros, 0.0), micro)
            grads = _constrain(jax.tree.map(lambda g: g / microbatches, grads))
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda x: x[-1], ms)

        err = opt_state.get("compress_err")
        grads, err = maybe_compress(grads, err, opt_cfg.compress_grads)
        params, new_state, opt_metrics = adamw_update(opt_cfg, params, grads, opt_state)
        if opt_cfg.compress_grads:
            new_state["compress_err"] = err
        metrics = dict(metrics, **opt_metrics, loss_total=loss)
        return params, new_state, metrics

    return train_step


def init_train_state(params, opt_cfg: AdamWConfig):
    state = init_opt_state(params)
    if opt_cfg.compress_grads:
        state["compress_err"] = jax.tree.map(
            lambda v: jnp.zeros(v.shape, F32), param_values(params))
    return state


@dataclass
class TrainLoop:
    """Checkpointed training loop with failure recovery.

    * saves a sharded checkpoint every `ckpt_every` steps (async commit),
    * on (re)start, resumes from the newest complete manifest,
    * per-step wall-time watchdog flags stragglers (slow steps re-logged with
      the step payload so an external scheduler can requeue/restart).
    """

    model_cfg: Any
    opt_cfg: AdamWConfig
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    straggler_factor: float = 3.0

    def run(self, params, batch_iter, steps: int, *, train_step=None,
            opt_state=None, on_metrics: Optional[Callable] = None):
        from repro.training import checkpoint as ckpt

        step0 = 0
        if self.ckpt_dir:
            # restore against templates so empty subtrees (e.g. non-parametric
            # norms) keep their structure
            opt_template = opt_state or init_train_state(params, self.opt_cfg)
            restored = ckpt.restore_latest(self.ckpt_dir, params, opt_template)
            if restored is not None:
                params, opt_state, step0 = restored
        if opt_state is None:
            opt_state = init_train_state(params, self.opt_cfg)
        if train_step is None:
            train_step = jax.jit(make_train_step(self.model_cfg, self.opt_cfg),
                                 donate_argnums=(0, 1))

        ema_dt = None
        stragglers = 0
        for step in range(step0, steps):
            batch = next(batch_iter)
            t0 = time.perf_counter()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            ema_dt = dt if ema_dt is None else 0.9 * ema_dt + 0.1 * dt
            if dt > self.straggler_factor * ema_dt:
                stragglers += 1
            if on_metrics:
                on_metrics(step, {k: float(v) for k, v in metrics.items()}, dt)
            if self.ckpt_dir and (step + 1) % self.ckpt_every == 0:
                ckpt.save(self.ckpt_dir, params, opt_state, step + 1, async_commit=True)
        if self.ckpt_dir:
            ckpt.wait_for_pending()   # never race an async save of this step
            if steps % self.ckpt_every != 0 or steps == step0:
                ckpt.save(self.ckpt_dir, params, opt_state, steps, async_commit=False)
        return params, opt_state, {"stragglers": stragglers}
