"""Data pipeline: deterministic synthetic token streams with packing,
sharded per data-parallel rank, plus the frontend-embedding stubs the
multimodal archs consume.

Offline container => synthetic corpus (a mixture of Zipfian token draws and
repeated n-gram "documents" so the LM has learnable structure); the pipeline
shape/packing/sharding logic is the production part.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    #: fraction of each sequence drawn from repeated n-grams (learnable signal)
    structure_frac: float = 0.5
    pad_id: int = 0


class SyntheticCorpus:
    """Deterministic, seekable synthetic corpus (restart == same stream)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # a small bank of n-grams that recur -> predictable structure
        self._ngrams = rng.integers(
            1, cfg.vocab_size, size=(256, 8), dtype=np.int32)
        zipf_w = 1.0 / np.arange(1, cfg.vocab_size + 1) ** 1.1
        self._zipf = zipf_w / zipf_w.sum()

    def sequence(self, index: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, index))
        out = np.empty(cfg.seq_len + 1, np.int32)
        i = 0
        while i < len(out):
            if rng.random() < cfg.structure_frac:
                gram = self._ngrams[rng.integers(len(self._ngrams))]
                n = min(len(gram), len(out) - i)
                out[i:i + n] = gram[:n]
                i += n
            else:
                n = min(int(rng.integers(4, 16)), len(out) - i)
                out[i:i + n] = rng.choice(cfg.vocab_size, size=n, p=self._zipf)
                i += n
        return out


def batches(cfg: DataConfig, *, dp_rank: int = 0, dp_size: int = 1,
            start_step: int = 0, model_cfg=None) -> Iterator[dict]:
    """Yield training batches, sharded by data-parallel rank.

    Deterministic in (seed, step, rank): a restarted job resumes the exact
    stream (fault-tolerance requirement — no data skew after recovery).
    """
    corpus = SyntheticCorpus(cfg)
    per_rank = cfg.global_batch // dp_size
    step = start_step
    while True:
        seqs = np.stack([
            corpus.sequence(step * cfg.global_batch + dp_rank * per_rank + i)
            for i in range(per_rank)])
        batch = {
            "tokens": seqs[:, :-1],
            "targets": seqs[:, 1:],
            "loss_mask": np.ones((per_rank, cfg.seq_len), np.float32),
        }
        if model_cfg is not None and getattr(model_cfg, "frontend", ""):
            rng = np.random.default_rng((cfg.seed, step, dp_rank, 7))
            emb = rng.standard_normal(
                (per_rank, model_cfg.frontend_tokens, model_cfg.d_model)).astype(np.float32) * 0.02
            if model_cfg.family == "vlm":
                batch["patch_embeds"] = emb
            else:
                batch["frames"] = emb
        yield batch
        step += 1
