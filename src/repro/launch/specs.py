"""input_specs(): ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero device allocation.  The dry-run lowers
train/prefill/decode steps against these.

Cache specs are produced by jax.eval_shape over model.init_cache, then
annotated with shardings by leaf path (batch -> data axes, cache seq ->
model axis: context-parallel KV for the decode shapes).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import data_axis_names
from repro.models import model as model_lib


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _batch_spec(mesh: Mesh, batch: int) -> tuple:
    axes = data_axis_names(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if batch % size == 0:
        return axes
    if "data" in axes and batch % mesh.shape["data"] == 0:
        return ("data",)
    return ()


def batch_sharding(mesh: Mesh, batch: int, extra_dims: int) -> NamedSharding:
    b_axes = _batch_spec(mesh, batch)
    spec = P(b_axes if b_axes else None, *([None] * extra_dims))
    return NamedSharding(mesh, spec)


def train_input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> dict:
    B, S = shape.global_batch, shape.seq_len
    tok = batch_sharding(mesh, B, 1)
    specs = {
        "tokens": _sds((B, S), jnp.int32, tok),
        "targets": _sds((B, S), jnp.int32, tok),
        "loss_mask": _sds((B, S), jnp.float32, tok),
    }
    if cfg.family == "vlm":
        specs["patch_embeds"] = _sds((B, cfg.frontend_tokens, cfg.d_model),
                                     cfg.dtype, batch_sharding(mesh, B, 2))
    if cfg.encoder_layers:
        specs["frames"] = _sds((B, cfg.frontend_tokens, cfg.d_model),
                               cfg.dtype, batch_sharding(mesh, B, 2))
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> dict:
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": _sds((B, S), jnp.int32, batch_sharding(mesh, B, 1))}
    if cfg.family == "vlm":
        specs["patch_embeds"] = _sds((B, cfg.frontend_tokens, cfg.d_model),
                                     cfg.dtype, batch_sharding(mesh, B, 2))
    if cfg.encoder_layers:
        specs["frames"] = _sds((B, cfg.frontend_tokens, cfg.d_model),
                               cfg.dtype, batch_sharding(mesh, B, 2))
    return specs


# ---------------------------------------------------------------------------------
# Cache specs (decode shapes)
# ---------------------------------------------------------------------------------

def _cache_leaf_sharding(mesh: Mesh, path: str, shape: tuple, batch: int,
                         stacked: bool) -> NamedSharding:
    """Sharding for one cache leaf, by name + rank.

    Layout: [layers?], batch -> data axes, cache-seq -> model (context
    parallel), trailing dims unsharded.  Dims that don't divide degrade to
    replicated.
    """
    axes: list = []
    dims = list(shape)
    i = 0
    if stacked:
        axes.append(None)
        i = 1
    b_axes = _batch_spec(mesh, batch)
    if i < len(dims) and dims[i] == batch and b_axes:
        axes.append(b_axes)
    elif i < len(dims):
        axes.append(None)
    i += 1
    # seq dim for kv caches: k/v/pos/c/k_pe and cross_k/v
    leaf = path.split("/")[-1]
    if leaf in ("k", "v", "pos", "c", "k_pe", "cross_k", "cross_v") and i < len(dims):
        if dims[i] % mesh.shape["model"] == 0 and dims[i] > 1:
            axes.append("model")
        else:
            axes.append(None)
        i += 1
    while i < len(dims):
        axes.append(None)
        i += 1
    return NamedSharding(mesh, P(*axes))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, mesh: Mesh) -> Any:
    enc_len = cfg.frontend_tokens if cfg.encoder_layers else 0
    abstract = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, batch, max_len, enc_len, dtype=cfg.dtype))
    flat = jax.tree_util.tree_flatten_with_path(abstract)
    specs = []
    for path, leaf in flat[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        stacked = cfg.scan_layers and key.startswith("blocks")
        sh = _cache_leaf_sharding(mesh, key, leaf.shape, batch, stacked)
        specs.append(_sds(leaf.shape, leaf.dtype, sh))
    return jax.tree.unflatten(flat[1], specs)


def decode_input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> dict:
    """decode_* / long_* lower serve_step: one new token against a KV cache
    of seq_len (per the assignment)."""
    B, S = shape.global_batch, shape.seq_len
    tok = batch_sharding(mesh, B, 1)
    return {
        "caches": cache_specs(cfg, B, S, mesh),
        "tokens": _sds((B, 1), jnp.int32, tok),
        "index": _sds((B,), jnp.int32,
                      NamedSharding(mesh, P(_batch_spec(mesh, B) or None))),
    }


def input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> dict:
    if shape.kind == "train":
        return train_input_specs(cfg, shape, mesh)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape, mesh)
    return decode_input_specs(cfg, shape, mesh)
