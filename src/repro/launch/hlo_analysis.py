"""Scan-aware HLO cost analysis.

XLA's built-in `compiled.cost_analysis()` counts a while-loop body ONCE —
with scan-over-layers (our default for depth-independent compile times) that
under-reports flops/bytes/collectives by the layer count.  This module walks
the post-optimization HLO text, builds per-computation symbol tables (operand
shapes are not inlined on every backend), recovers while-loop trip counts
from their condition computations, and accumulates:

  * flops            — dot/convolution ops (2 * prod(result) * prod(lhs
                       contracting dims)), recursing into fusions/calls,
                       x trip inside loop bodies
  * bytes accessed   — per top-level op: result + operand bytes (fusion
                       internals excluded: a fusion touches HBM only at its
                       boundary — the same model XLA uses), x trip in loops
  * collective bytes — result-shape bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       x trip in loops, per-kind breakdown

Validated against analytic counts in tests/test_hlo_analysis.py (a scanned
matmul must report length x one-matmul flops, etc.).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

DTYPE_BYTES = {"f64": 8, "c64": 8, "c128": 16, "f32": 4, "f16": 2, "bf16": 2,
               "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
               "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|branch_computations)=[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_elems_bytes(segment: str) -> tuple[int, int]:
    """(total elements, total bytes) over all typed shapes in the segment."""
    elems_total, bytes_total = 0, 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        elems_total += n
        bytes_total += n * DTYPE_BYTES[dtype]
    return elems_total, bytes_total


def _first_shape(segment: str) -> Optional[tuple[str, tuple[int, ...]]]:
    m = _SHAPE_RE.search(segment)
    if not m or m.group(1) not in DTYPE_BYTES:
        return None
    return m.group(1), tuple(int(d) for d in m.group(2).split(",") if d.strip())


@dataclass
class Op:
    name: str
    result_seg: str
    opcode: str
    rest: str          # everything after 'opcode('

    @property
    def result_bytes(self) -> int:
        return _shape_elems_bytes(self.result_seg)[1]

    @property
    def operand_seg(self) -> str:
        return self.rest.split(")")[0]

    def operand_names(self) -> list[str]:
        return _OPERAND_RE.findall(self.operand_seg)


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    table: dict = field(default_factory=dict)   # op name -> Op


def parse_computations(hlo: str) -> dict[str, "Computation"]:
    comps: dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and "->" in line and stripped.endswith("{"):
            m = _COMP_HDR_RE.match(stripped)
            if m:
                current = Computation(m.group(1))
                comps[current.name] = current
                if stripped.startswith("ENTRY"):
                    entry = current.name
                continue
        if current is None:
            continue
        m = _OP_RE.match(line)
        if m:
            name, result_seg, opcode, rest = m.groups()
            op = Op(name, result_seg, opcode, rest)
            current.ops.append(op)
            current.table[name] = op
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


#: Ops whose operands/results count as HBM traffic.  The CPU backend fuses
#: far less than TPU, so counting every op would inflate the memory term
#: ~10x with elementwise chains a TPU fuses for free.  We count the ops a
#: TPU executes as HBM-visible kernels (MXU ops, reductions, data movement,
#: fusion boundaries) — elementwise ops fuse into these.
_COUNT_BYTES_OPS = {"dot", "convolution", "fusion", "custom-call", "reduce",
                    "scatter", "gather", "dynamic-slice", "dynamic-update-slice",
                    "copy", "sort", "select-and-scatter", "concatenate", "pad",
                    "transpose", "reduce-window", "cholesky", "triangular-solve",
                    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute"}

#: fusions consisting only of these ops are "free on TPU": converts fuse into
#: the consuming MXU op's operand read (bf16 is the MXU input format), and
#: broadcast/reshape/bitcast are layout-only.  The CPU backend materializes
#: them as standalone kLoop fusions, which would spuriously dominate the
#: memory term (e.g. f32 casts of multi-GB KV caches in decode).
_FREE_FUSION_OPS = {"parameter", "constant", "convert", "bitcast", "copy",
                    "broadcast", "reshape", "get-tuple-element", "tuple"}


class HloCostModel:
    def __init__(self, hlo: str):
        self.comps = parse_computations(hlo)
        self.entry = self.comps.get("__entry__") or list(self.comps.values())[-1]
        self._flops_memo: dict[str, float] = {}
        self._bytes_memo: dict[str, float] = {}
        self._coll_memo: dict[str, dict] = {}

    # -- helpers ------------------------------------------------------------------------

    def _trip_count_from_cond(self, cond: Computation) -> int:
        """Fallback: the loop bound constant lives in the condition body
        (possibly feeding a fusion-wrapped compare)."""
        consts = []
        for op in cond.ops:
            if op.opcode == "constant" and op.result_seg.startswith("s32"):
                m = re.match(r"(\d+)", op.rest)
                if m:
                    consts.append(int(m.group(1)))
        return max(consts) if consts else 1

    def _while_parts(self, op: Op) -> tuple[Optional[Computation], int]:
        body = _BODY_RE.search(op.rest)
        # preferred: XLA's own loop analysis, serialized in backend_config
        m = re.search(r'known_trip_count[^}]*"n":"(\d+)"', op.rest)
        if m:
            trip = int(m.group(1))
        else:
            cond = _COND_RE.search(op.rest)
            trip = 1
            if cond and cond.group(1) in self.comps:
                trip = self._trip_count_from_cond(self.comps[cond.group(1)])
        if body and body.group(1) in self.comps:
            return self.comps[body.group(1)], trip
        return None, trip

    def _called(self, op: Op) -> list[Computation]:
        out = []
        for m in _CALLED_RE.finditer(op.rest):
            for sub in m.group(1).split(","):
                sub = sub.strip().lstrip("%")
                if sub in self.comps:
                    out.append(self.comps[sub])
        return out

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        res = _first_shape(op.result_seg)
        if res is None:
            return 0.0
        res_elems = 1
        for d in res[1]:
            res_elems *= d
        operands = op.operand_names()
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        if m and operands:
            lhs_op = comp.table.get(operands[0])
            if lhs_op is not None:
                lhs = _first_shape(lhs_op.result_seg)
                if lhs:
                    contract = 1
                    for idx in m.group(1).split(","):
                        if idx.strip() and int(idx) < len(lhs[1]):
                            contract *= lhs[1][int(idx)]
                    return 2.0 * res_elems * contract
        return 2.0 * res_elems

    def _conv_flops(self, comp: Computation, op: Op) -> float:
        res = _first_shape(op.result_seg)
        operands = op.operand_names()
        if res is None or len(operands) < 2:
            return 0.0
        res_elems = 1
        for d in res[1]:
            res_elems *= d
        kern_op = comp.table.get(operands[1])
        kern = _first_shape(kern_op.result_seg) if kern_op else None
        if not kern:
            return 2.0 * res_elems
        kern_elems = 1
        for d in kern[1]:
            kern_elems *= d
        out_ch = res[1][-1] if res[1] else 1
        return 2.0 * res_elems * max(1, kern_elems // max(1, out_ch))

    def _operand_bytes(self, comp: Computation, op: Op) -> int:
        total = 0
        for name in op.operand_names():
            ref = comp.table.get(name)
            if ref is not None:
                total += ref.result_bytes
        return total

    # -- flops ---------------------------------------------------------------------------

    def flops(self, comp: Optional[Computation] = None) -> float:
        comp = comp or self.entry
        if comp.name in self._flops_memo:
            return self._flops_memo[comp.name]
        self._flops_memo[comp.name] = 0.0  # cycle guard
        total = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                total += self._dot_flops(comp, op)
            elif op.opcode == "convolution":
                total += self._conv_flops(comp, op)
            elif op.opcode == "while":
                body, trip = self._while_parts(op)
                if body is not None:
                    total += trip * self.flops(body)
            else:
                for sub in self._called(op):
                    total += self.flops(sub)
        self._flops_memo[comp.name] = total
        return total

    # -- bytes ---------------------------------------------------------------------------

    def bytes_accessed(self, comp: Optional[Computation] = None, *,
                       count_copies: bool = True) -> float:
        """count_copies=False excludes `copy` ops: on TPU, loop-carried state
        (e.g. multi-GB KV caches flowing through a scan) is buffer-aliased
        in place, while the CPU backend materializes boundary copies that
        would dominate the memory term spuriously.  The dry-run records both
        numbers (memory_s / memory_s_no_copy)."""
        key = (comp or self.entry).name + ("" if count_copies else "#nc")
        comp = comp or self.entry
        if key in self._bytes_memo:
            return self._bytes_memo[key]
        self._bytes_memo[key] = 0.0
        total = 0.0
        for op in comp.ops:
            if op.opcode == "while":
                body, trip = self._while_parts(op)
                if body is not None:
                    total += trip * self.bytes_accessed(
                        body, count_copies=count_copies)
                continue
            if op.opcode in ("call", "conditional"):
                for sub in self._called(op):
                    total += self.bytes_accessed(sub, count_copies=count_copies)
                continue
            if op.opcode not in _COUNT_BYTES_OPS:
                continue
            if op.opcode == "copy" and not count_copies:
                continue
            if op.opcode == "fusion" and self._is_free_fusion(op):
                continue
            total += self._op_bytes(comp, op)
        self._bytes_memo[key] = total
        return total

    def _op_bytes(self, comp: Computation, op: Op) -> float:
        """HBM bytes for one op, with TPU in-place/slice semantics:

        * dynamic-update-slice (op or DUS-rooted fusion): the result aliases
          the big operand in place — traffic is the update payload (read) +
          the written slice, NOT the whole buffer: 2 x (operands - largest).
        * slice-read fusion (internal ops only dynamic-slice + free set):
          reads the slice, not the whole operand: ~2 x result.
        """
        op_names = op.operand_names()
        sizes = []
        for name in op_names:
            ref = comp.table.get(name)
            sizes.append(ref.result_bytes if ref is not None else 0)
        operand_total = sum(sizes)
        kinds = self._fusion_kinds(op) if op.opcode == "fusion" else set()
        if op.opcode == "dynamic-update-slice" or "dynamic-update-slice" in kinds:
            return 2.0 * max(0, operand_total - (max(sizes) if sizes else 0))
        if op.opcode in ("dynamic-slice", "gather") or (
                op.opcode == "fusion" and kinds and
                kinds <= {"dynamic-slice", "gather"}):
            # sliced/gathered reads touch only the extracted rows, not the
            # whole operand (scan xs slicing, embedding lookups)
            return 2.0 * op.result_bytes
        if op.opcode == "fusion" and "dynamic-slice" in kinds:
            # mixed slicing fusion (scan-body pattern: slice xs + compute):
            # whole-buffer operands are read only at the slice — cap each
            # operand's contribution at 8x the fusion result
            cap = 8.0 * max(op.result_bytes, 1)
            return op.result_bytes + sum(min(s, cap) for s in sizes)
        return op.result_bytes + operand_total

    def _fusion_kinds(self, op: Op) -> set:
        """Non-free opcodes inside a fusion's called computations."""
        kinds: set = set()
        for sub in self._called(op):
            for o in sub.ops:
                if o.opcode not in _FREE_FUSION_OPS:
                    kinds.add(o.opcode)
        return kinds

    def _is_free_fusion(self, op: Op) -> bool:
        return not self._fusion_kinds(op)

    # -- collectives -----------------------------------------------------------------------

    def collective_bytes(self, comp: Optional[Computation] = None) -> dict:
        comp = comp or self.entry
        if comp.name in self._coll_memo:
            return self._coll_memo[comp.name]
        acc = {k: 0.0 for k in COLLECTIVES}
        counts = {k: 0.0 for k in COLLECTIVES}
        self._coll_memo[comp.name] = {"bytes": dict(acc), "counts": dict(counts),
                                      "total_bytes": 0}

        def merge(sub: dict, mult: float):
            for k in COLLECTIVES:
                acc[k] += mult * sub["bytes"][k]
                counts[k] += mult * sub["counts"][k]

        for op in comp.ops:
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES and not op.opcode.endswith("-done"):
                acc[base] += op.result_bytes
                counts[base] += 1
            elif op.opcode == "while":
                body, trip = self._while_parts(op)
                if body is not None:
                    merge(self.collective_bytes(body), trip)
            else:
                for sub in self._called(op):
                    merge(self.collective_bytes(sub), 1)
        out = {"bytes": acc, "counts": counts,
               "total_bytes": int(sum(acc.values()))}
        self._coll_memo[comp.name] = out
        return out

    # -- marked kernel regions ----------------------------------------------------------
    # Attention/SSM cores run under jax.named_scope("KERNEL_<name>"); the
    # scope lands in each op's metadata op_name.  Tallying their bytes lets
    # the dry-run substitute a Pallas kernel's VMEM-resident byte profile
    # for the jnp reference implementation's HBM-materialized one.

    _MARKER_RE = re.compile(r'op_name="[^"]*KERNEL_(\w+)')

    def marked_bytes(self, comp: Optional[Computation] = None) -> dict:
        comp = comp or self.entry
        acc: dict[str, float] = {}

        def merge(sub: dict, mult: float):
            for k, v in sub.items():
                acc[k] = acc.get(k, 0.0) + mult * v

        for op in comp.ops:
            if op.opcode == "while":
                body, trip = self._while_parts(op)
                if body is not None:
                    merge(self.marked_bytes(body), trip)
                continue
            if op.opcode in ("call", "conditional"):
                for sub in self._called(op):
                    merge(self.marked_bytes(sub), 1)
                continue
            if op.opcode not in _COUNT_BYTES_OPS:
                continue
            m = self._MARKER_RE.search(op.rest)
            if m:
                acc[m.group(1)] = acc.get(m.group(1), 0.0) + \
                    op.result_bytes + self._operand_bytes(comp, op)
        return acc

    def trip_counts(self) -> list[int]:
        trips = []
        for comp in self.comps.values():
            if comp.name == "__entry__":
                continue
            for op in comp.ops:
                if op.opcode == "while":
                    _, trip = self._while_parts(op)
                    trips.append(trip)
        return trips


def analyze(hlo: str) -> dict:
    model = HloCostModel(hlo)
    coll = model.collective_bytes()
    return {
        "flops": model.flops(),
        "bytes_accessed": model.bytes_accessed(),
        "bytes_accessed_no_copy": model.bytes_accessed(count_copies=False),
        "collectives": coll,
        "trip_counts": model.trip_counts(),
        "marked_bytes": model.marked_bytes(),
    }
