"""Production mesh construction + the fabric partition vocabulary.

`make_production_mesh` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and smoke tests must keep seeing 1 device.

Mesh axes:
  single-pod: (16, 16)        ("data", "model")   — 256 chips
  multi-pod : (2, 16, 16)     ("pod", "data", "model") — 512 chips, DP across pods

The fabric partition vocabulary (§7 analogue) exposes mesh sub-blocks as the
confidential tenant shapes a scheduler may allocate (core/fabric.py enforces
the vocabulary; here we map shapes onto the mesh grid).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Degenerate mesh over whatever devices exist (CPU smoke / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def data_axis_names(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_chip_count(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))


def tenant_submesh(mesh: Mesh, size: int) -> Mesh:
    """Carve a fabric-valid tenant partition (1/2/4/8 chips) from the mesh
    grid — the §7 scheduling object on the ICI fabric."""
    from repro.core.fabric import PARTITION_VOCABULARY
    if size not in PARTITION_VOCABULARY:
        raise ValueError(f"tenant shape {size} not in {PARTITION_VOCABULARY}")
    flat = mesh.devices.reshape(-1)[:size]
    return Mesh(flat.reshape(1, size), ("data", "model"))
