import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

The two lines above MUST stay the first statements in this module — jax locks
the device count at first init, and the production meshes need 512 host
placeholder devices.  Everything else (smoke tests, benches) must see 1.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-check]

Per cell this produces artifacts/dryrun/<arch>__<shape>__<mesh>.json with:
  memory_analysis (per-device bytes), cost_analysis (flops / bytes accessed),
  collective bytes by op kind (parsed from the post-SPMD HLO), and the
  derived three-term roofline (§Roofline).
"""

import argparse
import dataclasses
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (SHAPES, ARCH_IDS, InputShape, ModelConfig,
                                get_config, shape_applicable)
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.launch import specs as specs_lib
from repro.models import model as model_lib
from repro.models.layers import Param, is_param, set_activation_resolver
from repro.models.shardings import ShardingRules
from repro.training.optimizer import AdamWConfig, param_values
from repro.training.train_loop import make_train_step

from repro.launch import hlo_analysis

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")

# TPU v5e hardware constants (assignment §Roofline)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


def _sds_tree(abstract, shardings):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract, shardings)


def pallas_kernel_bytes(cfg: ModelConfig, shape: InputShape, mesh) -> dict:
    """Analytic per-device HBM bytes of the Pallas kernels that replace the
    marked jnp reference regions (kernels keep logits/decay tiles in VMEM and
    touch HBM only for Q/K/V/O + states).

    Used for the kernel-substituted memory term: the jnp reference
    materializes O(S x block) intermediates to HBM that the TPU kernels never
    write.  train: fwd + recompute + backward ~ 4x forward traffic.
    """
    from repro.models.shardings import ShardingRules
    rules = ShardingRules(cfg, mesh)
    model_n = mesh.shape["model"]
    data_n = int(np.prod([mesh.shape[a] for a in mesh.axis_names if a != "model"]))
    B, S = shape.global_batch, shape.seq_len
    b_loc = max(1, B // data_n) if B % data_n == 0 else B
    dt = 2  # bf16

    h_loc = cfg.n_heads // model_n if rules.param_rules["heads"] == "model" else cfg.n_heads
    kv_loc = cfg.n_kv_heads // model_n if rules.param_rules["kv_heads"] == "model" else cfg.n_kv_heads
    D = cfg.head_dim
    passes = 4 if shape.kind == "train" else 1
    out: dict = {}

    if cfg.family != "ssm" and shape.kind != "decode":
        # flash attention: Q + O (h_loc) and K + V (kv_loc), per layer
        per_layer = (2 * b_loc * S * h_loc * D + 2 * b_loc * S * kv_loc * D) * dt
        n_attn = cfg.n_layers + cfg.encoder_layers
        out["flash_attention"] = passes * n_attn * per_layer
    if shape.kind == "decode" and cfg.family != "ssm":
        # paged decode: read the (seq-sharded) cache once + q/o
        seq_loc = S // model_n if S % model_n == 0 else S
        if cfg.use_mla:
            cache = b_loc * seq_loc * (cfg.kv_lora_rank + cfg.rope_head_dim) * dt
        else:
            cache = 2 * b_loc * seq_loc * cfg.n_kv_heads * D * dt
        n_full = len(cfg.global_layers) if cfg.sliding_window else cfg.n_layers
        n_win = cfg.n_layers - n_full if cfg.sliding_window else 0
        win_cache = 2 * b_loc * min(cfg.sliding_window or S, S) * cfg.n_kv_heads * D * dt
        out["paged_attention"] = n_full * cache + n_win * win_cache
    if cfg.ssm_kind:
        inner = cfg.ssm_expand * cfg.d_model
        inner_loc = inner // model_n if rules.param_rules["mlp"] == "model" else inner
        per_layer = 8 * b_loc * max(S if shape.kind != "decode" else 1, 1) * inner_loc * dt
        n_ssm = cfg.n_layers if cfg.family == "ssm" else cfg.n_layers  # hybrid: every layer
        key = "mlstm_scan" if cfg.ssm_kind == "xlstm" else "ssd_scan"
        out[key] = passes * n_ssm * per_layer
    return out


def build_cell(cfg: ModelConfig, shape: InputShape, mesh):
    """Returns (fn, kwargs-of-ShapeDtypeStructs) to lower for this cell."""
    from repro.launch.mesh import data_axis_names
    from repro.models.layers import set_moe_mesh
    rules = ShardingRules(cfg, mesh)
    set_activation_resolver(rules.resolver())
    set_moe_mesh(mesh, data_axis_names(mesh), "model")

    p_abstract = jax.eval_shape(
        lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg))
    p_shardings = rules.params_shardings(p_abstract)
    p_sds = jax.tree.map(
        lambda p, s: Param(jax.ShapeDtypeStruct(p.value.shape, p.value.dtype,
                                                sharding=s.value), p.axes),
        p_abstract, p_shardings, is_leaf=is_param)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        from repro.training.train_loop import init_train_state
        o_abstract = jax.eval_shape(lambda: init_train_state(p_abstract, opt_cfg))
        v_shard = param_values(p_shardings)
        o_sds = {
            "mu": jax.tree.map(lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
                               o_abstract["mu"], v_shard),
            "nu": jax.tree.map(lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
                               o_abstract["nu"], v_shard),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        batch_sds = specs_lib.train_input_specs(cfg, shape, mesh)
        step = make_train_step(cfg, opt_cfg, grad_shardings=v_shard)
        return step, (p_sds, o_sds, batch_sds)

    if shape.kind == "prefill":
        batch_sds = specs_lib.prefill_input_specs(cfg, shape, mesh)

        def prefill_step(params, batch):
            logits, caches, _ = model_lib.prefill(params, cfg, batch,
                                                  max_len=shape.seq_len)
            return logits, caches
        return prefill_step, (p_sds, batch_sds)

    # decode: one new token against a seq_len KV cache
    d = specs_lib.decode_input_specs(cfg, shape, mesh)

    def serve_step(params, caches, tokens, index):
        return model_lib.decode_step(params, cfg, caches, tokens, index)
    return serve_step, (p_sds, d["caches"], d["tokens"], d["index"])


def analyse(compiled, lowered, mesh, cfg, shape) -> dict:
    chips = mesh_chip_count(mesh)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    # scan-aware analysis (XLA's counts miss while-loop trip counts)
    hlo = compiled.as_text()
    own = hlo_analysis.analyze(hlo)
    flops = float(own["flops"])
    bytes_accessed = float(own["bytes_accessed"])
    bytes_no_copy = float(own["bytes_accessed_no_copy"])
    coll = own["collectives"]
    trips = own["trip_counts"]

    mem = {}
    ma = compiled.memory_analysis()
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        try:
            mem[attr] = int(getattr(ma, attr))
        except Exception:
            pass

    # the post-SPMD module is per-device: terms are per-chip seconds
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    memory_s_no_copy = bytes_no_copy / HBM_BW
    collective_s = coll["total_bytes"] / LINK_BW

    # kernel-substituted memory term: swap the marked jnp reference regions'
    # HBM traffic for the Pallas kernels' analytic profile
    marked = own.get("marked_bytes", {})
    kernel = pallas_kernel_bytes(cfg, shape, mesh)
    sub_bytes = max(0.0, bytes_accessed - sum(marked.values())) + sum(kernel.values())
    kernel_sub = {
        "marked_bytes": marked,
        "kernel_bytes": kernel,
        "bytes_substituted": sub_bytes,
        "memory_s": sub_bytes / HBM_BW,
    }

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else
                                   (shape.seq_len if shape.kind == "prefill" else 1))
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    return {
        "chips": chips,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "hlo_bytes_no_copy_per_device": bytes_no_copy,
        "memory_s_no_copy": memory_s_no_copy,
        "xla_cost_analysis": {"flops": xla_flops, "bytes_accessed": xla_bytes},
        "collectives": coll,
        "scan_trip_counts": trips,
        "memory_analysis": mem,
        "kernel_substitution": kernel_sub,
        **terms,
        "dominant": dominant,
        "model_flops_global": model_flops,
        "model_flops_per_device": model_flops / chips,
        "useful_flops_ratio": (model_flops / chips) / flops if flops else 0.0,
        "params_total": n_params,
        "params_active": n_active,
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, force: bool = False,
             out_dir: str = ARTIFACT_DIR, tag: str = "",
             overrides: dict = None, donate_cache: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}{tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "applicable": ok, "skip_reason": why, "status": "skip"}
    if ok:
        t0 = time.time()
        try:
            mesh = make_production_mesh(multi_pod=multi_pod)
            fn, args = build_cell(cfg, shape, mesh)
            donate = (1,) if (donate_cache and shape.kind == "decode") else ()
            with mesh:
                lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
                record.update(analyse(compiled, lowered, mesh, cfg, shape))
            record.update(status="ok", lower_s=round(t_lower, 1),
                          compile_s=round(t_compile, 1))
            print(compiled.memory_analysis())
        except Exception as e:
            record.update(status="error", error=f"{type(e).__name__}: {e}",
                          traceback=traceback.format_exc()[-4000:])
        finally:
            set_activation_resolver(None)
            from repro.models.layers import set_moe_mesh
            set_moe_mesh(None, (), None)

    with open(out_path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (hillclimb knob)")
    ap.add_argument("--donate-cache", action="store_true",
                    help="alias decode caches (in-place KV update)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.lstrip("-").isdigit() else v)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            if arch == "qwen3p6-27b":
                continue  # paper workload: serving benches cover it
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        rec = run_cell(arch, shape, multi_pod=args.multi_pod, force=args.force,
                       tag=args.tag, overrides=overrides,
                       donate_cache=args.donate_cache)
        status = rec.get("status")
        line = f"{arch:22s} {shape:12s} {rec['mesh']:10s} {status}"
        if status == "ok":
            line += (f"  dominant={rec['dominant']:<12s}"
                     f" compute={rec['compute_s']:.4f}s mem={rec['memory_s']:.4f}s"
                     f" coll={rec['collective_s']:.4f}s useful={rec['useful_flops_ratio']:.2f}")
        elif status == "error":
            line += f"  {rec['error'][:120]}"
        else:
            line += f"  ({rec['skip_reason'][:60]})"
        print(line, flush=True)


if __name__ == "__main__":
    main()
