"""Elastic rescale: remap a checkpoint trained on one mesh onto another.

    PYTHONPATH=src python -m repro.launch.elastic --ckpt-dir /tmp/ck \
        --arch olmo-1b --from-mesh 16x16 --to-mesh 8x8

Leaves are stored unsharded (training/checkpoint.py), so resharding is
placement: rebuild the target ShardingRules for the new mesh, device_put each
leaf with its new sharding, save back.  This is the scheduler-facing piece of
fault tolerance: a 512-chip job resumes on 256 chips (or a debug host) with
no format conversion.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import ARCH_IDS, get_config
from repro.models.model import init_params
from repro.models.shardings import ShardingRules
from repro.training import checkpoint as ckpt
from repro.training.optimizer import param_values


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    axes = ("pod", "data", "model")[-len(dims):]
    return jax.make_mesh(dims, axes)


def reshard(ckpt_dir: str, arch: str, to_mesh) -> dict:
    """Restore the newest checkpoint and re-place it for `to_mesh`."""
    cfg = get_config(arch)
    abstract = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    rules = ShardingRules(cfg, to_mesh)
    shardings = rules.params_shardings(abstract)

    # template with target shardings so restore places leaves directly
    from repro.models.layers import Param, is_param
    template = jax.tree.map(
        lambda a, s: Param(jax.ShapeDtypeStruct(a.value.shape, a.value.dtype,
                                                sharding=s.value), a.axes),
        abstract, shardings, is_leaf=is_param)
    params, opt, step = ckpt.restore(ckpt_dir, ckpt.committed_steps(ckpt_dir)[-1],
                                     template)
    return {"params": params, "opt": opt, "step": step,
            "mesh": dict(to_mesh.shape)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--to-mesh", default="1x1",
                    help="e.g. 16x16 or 2x16x16 (needs the dry-run's "
                         "XLA_FLAGS for >1 host device)")
    args = ap.parse_args()
    mesh = parse_mesh(args.to_mesh)
    out = reshard(args.ckpt_dir, args.arch, mesh)
    n = sum(v.size for v in jax.tree.leaves(param_values(out["params"])))
    print(f"resharded step {out['step']} ({n/1e6:.1f}M params) onto {out['mesh']}")


if __name__ == "__main__":
    main()
