"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b \
        --steps 300 --batch 8 --seq 128 [--smoke] [--ckpt-dir /tmp/ck]

On this CPU container `--smoke` (reduced config) is the practical mode; the
full configs are exercised via the dry-run.  The driver wires the full
production stack: data pipeline -> sharded train_step (mesh-aware when >1
device) -> checkpointed TrainLoop with straggler accounting.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_config, smoke_config
from repro.data.pipeline import DataConfig, batches
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="olmo-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M model: --d-model 512)")
    ap.add_argument("--layers", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    overrides = {}
    if args.d_model:
        overrides.update(d_model=args.d_model,
                         d_ff=4 * args.d_model if cfg.d_ff else 0,
                         head_dim=args.d_model // cfg.n_heads)
    if args.layers:
        overrides.update(n_layers=args.layers)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(v.shape)) for v in jax.tree.leaves(
        jax.tree.map(lambda p: p.value, params,
                     is_leaf=lambda x: hasattr(x, "axes"))))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1),
                          total_steps=args.steps,
                          compress_grads=args.compress_grads)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    data = batches(data_cfg, model_cfg=cfg)

    loop = TrainLoop(cfg, opt_cfg, ckpt_dir=args.ckpt_dir)
    t0 = time.perf_counter()

    def on_metrics(step, m, dt):
        if step % max(1, args.steps // 20) == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss={m['loss']:.4f}  "
                  f"grad_norm={m['grad_norm']:.2f}  lr={m['lr']:.2e}  "
                  f"{dt*1e3:.0f}ms/step", flush=True)

    from repro.training.train_loop import make_train_step
    train_step = jax.jit(make_train_step(cfg, opt_cfg,
                                         microbatches=args.microbatches))
    params, opt_state, info = loop.run(
        params, data, steps=args.steps, train_step=train_step,
        on_metrics=on_metrics)
    wall = time.perf_counter() - t0
    tokens = args.steps * args.batch * args.seq
    print(f"done: {wall:.1f}s, {tokens/wall:.0f} tok/s, "
          f"stragglers={info['stragglers']}")


if __name__ == "__main__":
    main()
