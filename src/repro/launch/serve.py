"""End-to-end serving driver: batched requests through the CC-aware engine.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --requests 16 --policy sync --cc

Runs real decode on CPU (reduced config) while the TransferGateway charges
bridge-law costs to the virtual clock — so one run reports both real tokens
and the CC economics of the chosen scheduling policy.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import ARCH_IDS, get_config, smoke_config
from repro.core.policy import SchedulingPolicy
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import Scheduler

POLICIES = {p.value: p for p in SchedulingPolicy}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--policy", choices=list(POLICIES), default=None,
                    help="default: CC-aware selection")
    ap.add_argument("--cc", action="store_true", help="confidential mode")
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    model = Model(cfg)
    policy = POLICIES[args.policy] if args.policy else None

    engine = ServingEngine(model, max_batch=args.batch, max_len=256,
                           policy=policy, cc_on=args.cc)
    sched = Scheduler(engine)
    print(f"arch={cfg.name} cc={'on' if args.cc else 'off'} "
          f"policy={engine.policy.value} batch={args.batch}")

    key = jax.random.PRNGKey(0)
    for i in range(args.requests):
        key, k = jax.random.split(key)
        prompt = list(map(int, jax.random.randint(k, (8,), 1, cfg.vocab_size)))
        sched.submit(Request(
            f"req-{i}", prompt=prompt,
            sampling=SamplingParams(temperature=args.temperature,
                                    max_new_tokens=args.max_new_tokens)))

    stats = sched.run()
    print("--- serving stats ---")
    for k, v in stats.items():
        print(f"{k:18s} {v:.4f}" if isinstance(v, float) else f"{k:18s} {v}")
    tput = stats["total_tokens"] / max(stats["virtual_time_s"], 1e-9)
    print(f"{'virtual tok/s':18s} {tput:.0f}  (bridge-law costed)")
    sample = engine.finished[0]
    print(f"sample request {sample.request_id}: prompt={sample.prompt[:4]}... "
          f"-> {sample.output_tokens[:8]}...")
    engine.close()


if __name__ == "__main__":
    main()
